//! Continuous-batching serving tests — engine-free.
//!
//! These pin the scheduler's acceptance bar without PJRT artifacts by
//! driving [`grace_moe::server::sched::simulate_serve`] with a
//! deterministic fake decode engine (next token = hash of the prefix,
//! so outputs depend only on the sequence — the same independence the
//! real greedy decoder has):
//!
//! * **determinism parity** — with a fixed seed the continuous scheduler
//!   produces token-for-token the same responses as the static-drain
//!   discipline on a closed-loop workload;
//! * **mid-flight admission** — a request arriving while a long request
//!   is in flight gets its first token strictly earlier (in time and in
//!   steps) than under the drain barrier;
//! * **open-loop Poisson serving** — the arrival generator drives the
//!   scheduler deterministically, queue-wait and TTFT populate, and the
//!   virtual clock respects the schedule.

use grace_moe::config::{ArrivalProcess, ServeLoad};
use grace_moe::server::sched::{simulate_serve, simulate_serve_events,
                               simulate_serve_with, SchedConfig,
                               SchedEvent, SchedMode};
use grace_moe::server::Request;
use grace_moe::stats::Rng;
use grace_moe::testutil::fake_decode_token as fake_next;
use grace_moe::testutil::FakeKvEngine;
use std::cell::RefCell;
use std::collections::HashMap;

const CTX: usize = 64;
const LAYERS: usize = 2;
const TILE_T: usize = 16;

fn cfg(mode: SchedMode, max_batch: usize, budget: usize) -> SchedConfig {
    SchedConfig {
        mode,
        max_batch,
        max_batch_tokens: budget,
        ctx: CTX,
        kv_cache: false,
        ..SchedConfig::default()
    }
}

/// Fake batched engine: per-step dispatch rounds follow the shared-tile
/// packing rule of the real batched forward
/// (`layers × ⌈step tokens / tile_t⌉`).
fn fake_step(seqs: &[(u64, &[i32], usize)])
             -> anyhow::Result<(Vec<i32>, usize)> {
    let tokens: usize = seqs.iter().map(|(_, ids, _)| ids.len()).sum();
    let rounds = LAYERS * tokens.div_ceil(TILE_T);
    Ok((seqs.iter().map(|(_, ids, _)| fake_next(ids)).collect(), rounds))
}

fn req(id: u64, prompt: usize, new_tokens: usize) -> Request {
    Request {
        id,
        prompt: (0..prompt)
            .map(|i| ((id as usize * 131 + i * 17) % 512) as i32)
            .collect(),
        max_new_tokens: new_tokens,
        priority: 0,
    }
}

fn preq(id: u64, prompt: usize, new_tokens: usize, priority: usize)
        -> Request {
    Request { priority, ..req(id, prompt, new_tokens) }
}

#[test]
fn continuous_matches_static_drain_token_for_token() {
    // Closed loop: six requests of varying shape, both disciplines.
    let arrivals = |_: ()| -> Vec<(Request, f64)> {
        (0..6).map(|id| (req(id, 4 + id as usize, 5), 0.0)).collect()
    };
    let run = |mode| {
        simulate_serve(cfg(mode, 3, 64), arrivals(()), fake_step,
                       |_, _| 1.0)
            .unwrap()
    };
    let (r_static, m_static) = run(SchedMode::StaticDrain);
    let (r_cont, m_cont) = run(SchedMode::Continuous);
    assert_eq!(r_static.len(), 6);
    assert_eq!(r_cont.len(), 6);
    for (a, b) in r_static.iter().zip(&r_cont) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "request {}: scheduling changed decoded tokens", a.id);
        assert_eq!(a.tokens.len(), 5);
    }
    assert_eq!(m_static.generated_tokens, m_cont.generated_tokens);
    // Continuous refills the batch as requests retire, so it never runs
    // more steps than the drain barrier does.
    assert!(m_cont.steps <= m_static.steps,
            "continuous {} steps !<= static {}", m_cont.steps,
            m_static.steps);
}

#[test]
fn mid_flight_admission_beats_the_drain_barrier_on_ttft() {
    // One long request in flight; a short one arrives mid-generation.
    let arrivals = vec![(req(0, 8, 40), 0.0), (req(1, 8, 4), 0.5)];
    let run = |mode| {
        simulate_serve(cfg(mode, 4, 256), arrivals.clone(), fake_step,
                       |_, _| 1.0)
            .unwrap()
    };
    let (_, m_static) = run(SchedMode::StaticDrain);
    let (_, m_cont) = run(SchedMode::Continuous);
    let late = |m: &grace_moe::metrics::ServeMetrics| {
        m.per_request.iter().find(|t| t.id == 1).copied().unwrap()
    };
    let (s, c) = (late(&m_static), late(&m_cont));
    // Static drain: request 1 waits behind the whole 40-token drain.
    assert!(s.queue_wait > 30.0, "drain barrier wait: {}", s.queue_wait);
    // Continuous: admitted at the next step boundary.
    assert!(c.queue_wait < 2.0, "mid-flight wait: {}", c.queue_wait);
    assert!(
        c.ttft < s.ttft,
        "continuous TTFT {} !< drain-barrier TTFT {}", c.ttft, s.ttft
    );
    assert!(c.first_token_step < s.first_token_step);
    // The long request completes in both runs.
    assert!(late(&m_static).latency > 0.0);
    assert!(late(&m_cont).latency > 0.0);
}

#[test]
fn open_loop_poisson_is_deterministic_and_complete() {
    let load = ServeLoad {
        requests: 24,
        prompt: 6,
        new_tokens: 4,
        arrival: ArrivalProcess::Poisson { rate: 2.0 },
    };
    let run = || {
        let mut rng = Rng::new(11);
        let times = load.arrival_times(&mut rng);
        let arrivals: Vec<(Request, f64)> = (0..load.requests)
            .map(|i| (req(i as u64, load.prompt, load.new_tokens),
                      times[i]))
            .collect();
        let last_arrival = *times.last().unwrap();
        let (responses, metrics) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 48),
            arrivals,
            fake_step,
            |tokens, _| tokens as f64 * 2e-3,
        )
        .unwrap();
        (responses, metrics, last_arrival)
    };
    let (responses, metrics, last_arrival) = run();
    assert_eq!(responses.len(), 24);
    for r in &responses {
        assert_eq!(r.tokens.len(), 4);
    }
    assert_eq!(metrics.generated_tokens, 24 * 4);
    assert_eq!(metrics.ttft.len(), 24);
    assert_eq!(metrics.queue_wait.len(), 24);
    assert!(metrics.queue_wait.iter().all(|&w| w >= 0.0));
    // The virtual clock cannot finish before the last arrival.
    assert!(metrics.wall_time >= last_arrival,
            "wall {} < last arrival {last_arrival}", metrics.wall_time);
    // Deterministic end to end.
    let (r2, m2, _) = run();
    let tok = |rs: &[grace_moe::server::Response]| {
        rs.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(tok(&responses), tok(&r2));
    assert_eq!(metrics.ttft, m2.ttft);
    assert_eq!(metrics.steps, m2.steps);
    assert_eq!(metrics.dispatch_rounds, m2.dispatch_rounds);
}

#[test]
fn batched_step_rounds_undercut_the_per_sequence_path() {
    // The dispatch-density claim at the scheduler level: a microbatch of
    // short sequences costs ⌈Σ len / tile_t⌉ rounds per layer batched,
    // vs Σ ⌈len / tile_t⌉ when each sequence runs its own forward (the
    // seed server). Count both on the same schedule.
    let arrivals: Vec<(Request, f64)> =
        (0..6).map(|id| (req(id, 5, 6), 0.0)).collect();
    let mut batched = 0usize;
    let mut per_seq = 0usize;
    let (_, metrics) = simulate_serve(
        cfg(SchedMode::Continuous, 6, 256),
        arrivals,
        |seqs| {
            let (next, rounds) = fake_step(seqs)?;
            batched += rounds;
            per_seq += seqs
                .iter()
                .map(|(_, ids, _)| LAYERS * ids.len().div_ceil(TILE_T))
                .sum::<usize>();
            Ok((next, rounds))
        },
        |_, _| 1.0,
    )
    .unwrap();
    assert_eq!(metrics.dispatch_rounds, batched);
    assert!(
        batched < per_seq,
        "shared tiles must cut dispatch rounds: {batched} !< {per_seq}"
    );
    assert!(metrics.rounds_per_token() > 0.0);
}

#[test]
fn kv_cached_decode_is_token_identical_across_modes_and_loads() {
    // THE sim-level KV parity property: across Continuous/StaticDrain ×
    // closed/Poisson loads, cached decode produces token-for-token the
    // same responses as full recompute, while computing strictly fewer
    // tokens and issuing strictly fewer dispatch rounds. The fake engine
    // also errors if the scheduler's cached-length pricing ever drifts
    // from the engine's cache state, so the lockstep is checked at every
    // step of every run.
    let arrivals = |poisson: bool| -> Vec<(Request, f64)> {
        if poisson {
            let load = ServeLoad {
                requests: 16,
                prompt: 6,
                new_tokens: 5,
                arrival: ArrivalProcess::Poisson { rate: 3.0 },
            };
            let mut rng = Rng::new(17);
            let times = load.arrival_times(&mut rng);
            (0..load.requests)
                .map(|i| (req(i as u64, load.prompt, load.new_tokens),
                          times[i]))
                .collect()
        } else {
            (0..8)
                .map(|id| (req(id, 4 + id as usize % 5, 5), 0.0))
                .collect()
        }
    };
    for mode in [SchedMode::Continuous, SchedMode::StaticDrain] {
        for poisson in [false, true] {
            let run = |kv: bool| {
                let mut c = cfg(mode, 4, 256);
                c.kv_cache = kv;
                let eng = std::cell::RefCell::new(
                    FakeKvEngine::new(LAYERS, TILE_T, kv));
                simulate_serve_with(
                    c,
                    arrivals(poisson),
                    |seqs| eng.borrow_mut().step(seqs),
                    |_, _| 1.0,
                    |id| eng.borrow_mut().retire(id),
                )
                .unwrap()
            };
            let (r_kv, m_kv) = run(true);
            let (r_re, m_re) = run(false);
            assert_eq!(r_kv.len(), r_re.len());
            for (a, b) in r_kv.iter().zip(&r_re) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens, b.tokens,
                    "request {} ({mode:?}, poisson={poisson}): KV cache \
                     changed decoded tokens", a.id
                );
            }
            assert_eq!(m_kv.generated_tokens, m_re.generated_tokens);
            // The budget never binds here, so both runs walk the same
            // schedule and the pricing identity is exact: every token
            // recompute pays is either computed or a cache hit.
            assert_eq!(m_kv.computed_tokens + m_kv.cached_tokens,
                       m_re.computed_tokens);
            assert_eq!(m_re.cached_tokens, 0);
            assert!(
                m_kv.computed_tokens < m_re.computed_tokens,
                "({mode:?}, poisson={poisson}) cached {} !< recompute {}",
                m_kv.computed_tokens, m_re.computed_tokens
            );
            assert!(
                m_kv.dispatch_rounds < m_re.dispatch_rounds,
                "({mode:?}, poisson={poisson}) cached decode must issue \
                 fewer rounds: {} !< {}",
                m_kv.dispatch_rounds, m_re.dispatch_rounds
            );
            assert!(m_kv.cache_hit_rate() > 0.0);
        }
    }
}

#[test]
fn kv_parity_survives_a_binding_token_budget() {
    // With a budget tight enough to change microbatch composition
    // between the two pricings, per-request tokens still cannot differ
    // (next-token is a pure function of the prefix).
    for budget in [16usize, 24, 48] {
        let run = |kv: bool| {
            let mut c = cfg(SchedMode::Continuous, 8, budget);
            c.kv_cache = kv;
            let eng = std::cell::RefCell::new(
                FakeKvEngine::new(LAYERS, TILE_T, kv));
            simulate_serve_with(
                c,
                (0..6).map(|id| (req(id, 8, 6), 0.0)).collect(),
                |seqs| eng.borrow_mut().step(seqs),
                |_, _| 1.0,
                |id| eng.borrow_mut().retire(id),
            )
            .unwrap()
            .0
        };
        let r_kv = run(true);
        let r_re = run(false);
        for (a, b) in r_kv.iter().zip(&r_re) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens,
                       "budget {budget}, request {}: tokens diverged",
                       a.id);
        }
    }
}

#[test]
fn kv_caches_are_evicted_at_retirement() {
    // No cache growth over a long run: the number of live caches is
    // bounded by the batch size and returns to zero when the workload
    // drains.
    let mut c = cfg(SchedMode::Continuous, 3, 64);
    c.kv_cache = true;
    let eng = std::cell::RefCell::new(
        FakeKvEngine::new(LAYERS, TILE_T, true));
    let arrivals: Vec<(Request, f64)> =
        (0..24).map(|id| (req(id, 5, 4), 0.0)).collect();
    let (responses, _) = simulate_serve_with(
        c,
        arrivals,
        |seqs| eng.borrow_mut().step(seqs),
        |_, _| 1.0,
        |id| eng.borrow_mut().retire(id),
    )
    .unwrap();
    assert_eq!(responses.len(), 24);
    let eng = eng.into_inner();
    assert_eq!(eng.live_caches(), 0,
               "caches must all be evicted once the workload drains");
    assert!(eng.peak_caches() <= 3,
            "cache count exceeded the live batch bound: {}",
            eng.peak_caches());
}

#[test]
fn queue_wait_reflects_budget_pressure() {
    // With a tight budget, later requests measurably queue; with a loose
    // one they do not.
    let arrivals = |_: ()| -> Vec<(Request, f64)> {
        (0..8).map(|id| (req(id, 8, 8), 0.0)).collect()
    };
    let run = |budget| {
        simulate_serve(cfg(SchedMode::Continuous, 8, budget),
                       arrivals(()), fake_step, |_, _| 1.0)
            .unwrap()
            .1
    };
    let tight = run(16);
    let loose = run(4096);
    let p95 = |m: &grace_moe::metrics::ServeMetrics| {
        m.queue_wait_summary().unwrap().p95()
    };
    assert!(p95(&tight) > p95(&loose),
            "tight {} !> loose {}", p95(&tight), p95(&loose));
    assert_eq!(loose.queue_wait.iter().filter(|&&w| w > 0.0).count(), 0,
               "loose budget admits everyone at t=0");
}

#[test]
fn preempt_resume_parity_with_cache_retained_and_dropped() {
    // A high-priority arrival evicts the lone low-priority decode. The
    // victim's tokens must be unchanged whether its KV survived the
    // eviction warm (retain cap = ∞) or was dropped and re-prefilled on
    // resume (retain cap = 0) — eviction may change timing and cost,
    // never outputs. The fake engine errors if the scheduler's cached
    // pricing drifts from the engine-side cache on either path.
    let solo = {
        let mut c = cfg(SchedMode::Continuous, 2, 12);
        c.kv_cache = true;
        let eng = RefCell::new(FakeKvEngine::new(LAYERS, TILE_T, true));
        simulate_serve_with(
            c,
            vec![(preq(0, 10, 20, 1), 0.0)],
            |seqs| eng.borrow_mut().step(seqs),
            |_, _| 1.0,
            |id| eng.borrow_mut().retire(id),
        )
        .unwrap()
        .0
    };
    let mut computed = Vec::new();
    for retain in [usize::MAX, 0usize] {
        let mut c = cfg(SchedMode::Continuous, 2, 12);
        c.kv_cache = true;
        c.preempt = true;
        c.retain_cache_tokens = retain;
        let eng = RefCell::new(FakeKvEngine::new(LAYERS, TILE_T, true));
        let drops = RefCell::new(0usize);
        let (responses, m) = simulate_serve_events(
            c,
            vec![(preq(0, 10, 20, 1), 0.0), (preq(1, 12, 3, 0), 3.0)],
            |seqs| eng.borrow_mut().step(seqs),
            |_, _| 1.0,
            |e| match *e {
                SchedEvent::Preempted { id, cache_dropped } => {
                    eng.borrow_mut().preempt(id, cache_dropped);
                    if cache_dropped {
                        *drops.borrow_mut() += 1;
                    }
                }
                SchedEvent::Retired { id } => {
                    eng.borrow_mut().retire(id);
                }
                _ => {}
            },
        )
        .unwrap();
        assert_eq!(m.preemptions, 1, "retain={retain}");
        assert_eq!(m.resumes, 1, "retain={retain}");
        // Under the zero cap the victim's cache is dropped; under the
        // unbounded cap it stays warm.
        assert_eq!(*drops.borrow(), usize::from(retain == 0),
                   "retain={retain}");
        let r0 = responses.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(r0.tokens, solo[0].tokens,
                   "retain={retain}: eviction changed request 0's \
                    tokens");
        assert_eq!(r0.tokens.len(), 20);
        assert_eq!(
            responses.iter().find(|r| r.id == 1).unwrap().tokens.len(),
            3
        );
        assert_eq!(eng.borrow().live_caches(), 0,
                   "retain={retain}: caches leaked past the drain");
        assert_eq!(
            m.per_request.iter().find(|t| t.id == 0).unwrap()
                .preemptions,
            1
        );
        computed.push(m.computed_tokens);
    }
    // Dropping the cache forces a re-prefill of the whole prefix, so
    // the zero-cap run computes strictly more tokens.
    assert!(computed[1] > computed[0],
            "drop-path compute {} !> retain-path {}", computed[1],
            computed[0]);
}

#[test]
fn preemption_bounds_short_request_ttft_fifo_starves_it() {
    // Starvation regression: a short class-0 request arriving behind a
    // long class-1 decode under a budget too tight to share. Without
    // preemption it waits for the entire 30-token drain; with it, the
    // long request is evicted and the short one's TTFT stays bounded.
    let arrivals =
        vec![(preq(0, 16, 30, 1), 0.0), (preq(1, 8, 2, 0), 2.0)];
    let run = |preempt: bool| {
        let mut c = cfg(SchedMode::Continuous, 4, 24);
        c.preempt = preempt;
        simulate_serve(c, arrivals.clone(), fake_step, |_, _| 1.0)
            .unwrap()
    };
    let (r_fifo, m_fifo) = run(false);
    let (r_pre, m_pre) = run(true);
    let ttft1 = |m: &grace_moe::metrics::ServeMetrics| {
        m.per_request.iter().find(|t| t.id == 1).unwrap().ttft
    };
    // FIFO: request 1 starves behind the drain (admitted ~t=30).
    assert!(ttft1(&m_fifo) > 25.0,
            "fifo TTFT {} not starved", ttft1(&m_fifo));
    assert_eq!(m_fifo.preemptions, 0);
    // Preemption: first token within a few steps of arrival.
    assert!(ttft1(&m_pre) < 5.0,
            "preempt TTFT {} not bounded", ttft1(&m_pre));
    assert_eq!(m_pre.preemptions, 1);
    assert_eq!(m_pre.resumes, 1);
    // The evicted long request still decodes to completion, token for
    // token.
    assert_eq!(r_fifo[0].id, 0);
    assert_eq!(r_pre[0].id, 0);
    assert_eq!(r_fifo[0].tokens, r_pre[0].tokens,
               "eviction changed the long request's tokens");
    assert_eq!(r_pre[0].tokens.len(), 30);
}

#[test]
fn retire_hook_fires_exactly_once_across_preempt_resume() {
    // The retirement hook of simulate_serve_with is the KV-eviction
    // contract: exactly one fire per admitted request, no matter how
    // often it was preempted and resumed mid-decode.
    let mut c = cfg(SchedMode::Continuous, 4, 24);
    c.preempt = true;
    let fired: RefCell<HashMap<u64, usize>> =
        RefCell::new(HashMap::new());
    let (responses, m) = simulate_serve_with(
        c,
        vec![(preq(0, 16, 30, 1), 0.0), (preq(1, 8, 2, 0), 2.0)],
        fake_step,
        |_, _| 1.0,
        |id| *fired.borrow_mut().entry(id).or_insert(0) += 1,
    )
    .unwrap();
    assert_eq!(m.preemptions, 1,
               "trace must actually exercise eviction");
    assert_eq!(responses.len(), 2);
    let fired = fired.into_inner();
    assert_eq!(fired.len(), 2, "{fired:?}");
    assert!(fired.values().all(|&n| n == 1),
            "a request retired more than once: {fired:?}");
}
