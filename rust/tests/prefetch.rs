//! Integration tests for predictive expert prefetching and the
//! two-tier weight cache: the PR-10 acceptance invariants pinned from
//! outside the crate.
//!
//! * **parity** — a weight tier changes *when* weights move, never
//!   *what* is computed: routing, traffic, and load metrics are
//!   token-for-token identical with prefetch on vs off, on both
//!   backends (prefetch only ever adds stall time);
//! * **occupancy** — no GPU's hot tier ever exceeds `--weight-budget`
//!   experts, whatever the demand/prefetch interleaving (the
//!   acceptance property test);
//! * **determinism** — same seed ⇒ identical staging counters and
//!   timing across reruns, including on the contended DES network;
//! * **validation** — degenerate knobs (`--weight-budget 0`,
//!   `--prefetch-k` past the expert count, NaN alpha) fail loudly at
//!   the config boundary, not as NaNs mid-run.

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::comm::{CommBackend, CommBackendKind};
use grace_moe::config::{ModelSpec, PrefetchConfig, Workload};
use grace_moe::engine::sim::{build_placement, simulate_with_contention,
                             SimConfig};
use grace_moe::engine::PrefetchEngine;
use grace_moe::metrics::PrefetchStats;
use grace_moe::routing::{Assignment, Dispatcher, RoutingPolicy};
use grace_moe::stats::Rng;

fn small_sim(backend: CommBackendKind) -> SimConfig {
    let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
    let mut sim = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload { batch: 8, prefill: 8, decode: 2 },
    );
    sim.profile_tokens = 256;
    sim.max_chunk = 256;
    sim.comm_backend = backend;
    sim
}

// --- parity -----------------------------------------------------------------

#[test]
fn prefetch_preserves_routing_token_for_token() {
    for backend in [CommBackendKind::Analytic, CommBackendKind::Des] {
        let off = small_sim(backend);
        let mut on = off.clone();
        on.prefetch = Some(PrefetchConfig::default());
        let sys = SystemSpec::grace(0.15);
        let placement = build_placement(&sys, &off);
        let (mo, _) = simulate_with_contention(&sys, &off, &placement);
        let (mp, _) = simulate_with_contention(&sys, &on, &placement);
        // Same tokens through the same plans: every routing-derived
        // metric is bit-identical.
        assert_eq!(mp.tokens, mo.tokens, "{backend:?}: token parity");
        assert_eq!(mp.cross_bytes, mo.cross_bytes, "{backend:?}");
        assert_eq!(mp.intra_bytes, mo.intra_bytes, "{backend:?}");
        assert_eq!(mp.launches, mo.launches, "{backend:?}");
        assert_eq!(mp.layer_load_std, mo.layer_load_std, "{backend:?}");
        // The tier only ever *adds* stall time to the critical path.
        assert!(mp.e2e_time >= mo.e2e_time,
                "{backend:?}: staging cannot speed up the run \
                 ({} vs {})", mp.e2e_time, mo.e2e_time);
        assert_eq!(mo.prefetch, PrefetchStats::default(),
                   "no tier, no counters");
        assert!(mp.prefetch.stalls > 0, "{backend:?}: cold start stalls");
        assert!(mp.prefetch.stall_steps > 0, "{backend:?}");
        assert!(mp.prefetch.demand_bytes > 0.0, "{backend:?}");
    }
}

// --- occupancy --------------------------------------------------------------

#[test]
fn hot_tier_occupancy_never_exceeds_weight_budget() {
    let cfg = small_sim(CommBackendKind::Analytic);
    let sys = SystemSpec::grace(0.15);
    let placement = build_placement(&sys, &cfg);
    let budget = 2;
    let pc = PrefetchConfig {
        predictive: true,
        k: 3,
        weight_budget: budget,
        alpha: 0.4,
    };
    let mut eng = PrefetchEngine::new(pc, cfg.model.moe_layers,
                                      cfg.model.experts,
                                      cfg.topo.num_gpus(),
                                      cfg.model.expert_bytes());
    let mut backend = CommBackend::new(CommBackendKind::Analytic,
                                       &cfg.topo);
    let mut dispatcher = Dispatcher::new(cfg.topo.clone(),
                                         RoutingPolicy::Tar.build(),
                                         cfg.model.token_bytes());
    let mut rng = Rng::new(7);
    for round in 0..8usize {
        for layer in 0..cfg.model.moe_layers {
            let lp = &placement.layers[layer];
            let batch: Vec<Assignment> = (0..32)
                .map(|t| Assignment {
                    token: t,
                    expert: rng.index(cfg.model.experts),
                    src: t % cfg.topo.num_gpus(),
                })
                .collect();
            let plan = dispatcher.dispatch(lp, layer, &batch, &mut rng);
            let at = round as f64;
            eng.demand_pass(layer, &plan, &mut backend, &cfg.topo, at);
            eng.prefetch_pass(layer, &plan, lp, &mut backend, &cfg.topo,
                              at);
            for gpu in 0..eng.num_tiers() {
                assert!(eng.occupancy(gpu) <= budget,
                        "GPU {gpu} tier holds {} > budget {budget} at \
                         round {round} layer {layer}",
                        eng.occupancy(gpu));
            }
        }
    }
    assert!(eng.stats().evictions > 0,
            "a {budget}-expert budget under {}-expert demand must evict",
            cfg.model.experts);
    assert!(eng.stats().prefetches > 0,
            "prediction never fired over 8 correlated rounds");
    eng.finish();
    assert!(eng.stats().wasted_bytes <= eng.stats().prefetch_bytes,
            "waste cannot exceed what was prefetched");
}

// --- determinism ------------------------------------------------------------

#[test]
fn prefetch_metrics_are_deterministic_across_reruns() {
    let mut cfg = small_sim(CommBackendKind::Des);
    cfg.prefetch = Some(PrefetchConfig::default());
    let sys = SystemSpec::grace(0.15);
    let placement = build_placement(&sys, &cfg);
    let (a, ca) = simulate_with_contention(&sys, &cfg, &placement);
    let (b, cb) = simulate_with_contention(&sys, &cfg, &placement);
    assert_eq!(a.prefetch, b.prefetch,
               "staging counters diverge across reruns");
    assert_eq!(a.e2e_time, b.e2e_time);
    assert_eq!(a.a2a_time, b.a2a_time);
    let (ca, cb) = (ca.expect("DES reports"), cb.expect("DES reports"));
    assert_eq!(ca.event_digest, cb.event_digest,
               "event logs diverge across reruns");
    assert!(ca.transfers > 0);
}

// --- validation -------------------------------------------------------------

#[test]
fn degenerate_prefetch_configs_fail_loudly() {
    let ok = PrefetchConfig::default();
    assert!(ok.validate(64).is_ok());
    assert!(PrefetchConfig { weight_budget: 0, ..ok }
                .validate(64)
                .is_err(),
            "--weight-budget 0 must be rejected");
    assert!(PrefetchConfig { k: 0, ..ok }.validate(64).is_err(),
            "zero prediction depth must be rejected");
    assert!(PrefetchConfig { k: 65, ..ok }.validate(64).is_err(),
            "--prefetch-k past the expert count must be rejected");
    assert!(PrefetchConfig { alpha: f64::NAN, ..ok }
                .validate(64)
                .is_err(),
            "NaN alpha must be rejected");
    assert!(PrefetchConfig { alpha: 0.0, ..ok }.validate(64).is_err());
    assert!(PrefetchConfig { alpha: 1.5, ..ok }.validate(64).is_err());
}
