//! Scheduler property/fuzz suite: seeded random workloads through every
//! discipline × KV × preemption combination, with the core safety
//! invariants asserted after *every* scheduler interaction — not just at
//! the end of a run.
//!
//! Invariants pinned here (the scheduler's contract with the server):
//!
//! * a Continuous step never computes more than `max_batch_tokens`
//!   unless the batch is a single over-budget sequence (the no-stall
//!   escape);
//! * the live batch never exceeds `max_batch`;
//! * `cached_len` never exceeds the sequence length, and is always 0
//!   under recompute pricing;
//! * preempted sequences never hold more than `retain_cache_tokens` of
//!   warm KV between them;
//! * the engine-side KV-cache map (mirrored by
//!   [`grace_moe::testutil::FakeKvEngine`] off the event stream) stays
//!   in lockstep with the scheduler's pricing and is empty at exit;
//! * every offered request lands in done ∪ rejected exactly once, and
//!   every stepped request fires exactly one `Retired` event no matter
//!   how often it was preempted and resumed.
//!
//! Case count defaults to a quick smoke; CI raises it via
//! `SCHED_FUZZ_CASES`. A failing case panics with its seed — replay
//! exactly that case with `SCHED_FUZZ_SEED=<seed> cargo test --test
//! sched_properties replay`.

use grace_moe::server::sched::{SchedConfig, SchedEvent, SchedMode,
                               Scheduler};
use grace_moe::server::Request;
use grace_moe::stats::Rng;
use grace_moe::testutil::{check, check_seed, prop_assert, FakeKvEngine,
                          PropResult};
use std::collections::{HashMap, HashSet};

/// Hard ceiling on steps per case: the workloads are tiny (≤ 12
/// requests × ≤ 6 tokens), so hitting this means the scheduler stopped
/// making progress.
const MAX_STEPS: usize = 20_000;

/// Random but always-valid scheduler config: every mode × KV ×
/// preemption combination, tight batch/budget bounds so admission
/// pressure (and with it preemption) actually occurs.
fn random_config(rng: &mut Rng) -> SchedConfig {
    let mode = if rng.chance(0.5) {
        SchedMode::Continuous
    } else {
        SchedMode::StaticDrain
    };
    let retain = match rng.index(3) {
        0 => 0,
        1 => 8,
        _ => usize::MAX,
    };
    // Deadlines drawn around the virtual-clock scale below: some shed,
    // some never fire.
    let ttft_slo = if rng.chance(0.3) {
        (0..1 + rng.index(3)).map(|_| rng.range_f64(0.5, 50.0)).collect()
    } else {
        Vec::new()
    };
    SchedConfig {
        mode,
        max_batch: 1 + rng.index(4),
        max_batch_tokens: 8 + rng.index(57),
        ctx: 32,
        kv_cache: rng.chance(0.5),
        preempt: rng.chance(0.5),
        retain_cache_tokens: retain,
        ttft_slo,
    }
}

/// Random valid workload: ids are dense, prompts fit the context with
/// generation room to spare, priorities span three classes, and some
/// requests ask for zero tokens (the retire-at-admission edge).
fn random_arrivals(rng: &mut Rng) -> Vec<(Request, f64)> {
    let n = 1 + rng.index(12);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.range_f64(0.0, 4.0);
            let prompt = 1 + rng.index(8);
            let req = Request {
                id: i as u64,
                prompt: (0..prompt)
                    .map(|p| (i * 100 + p) as i32)
                    .collect(),
                max_new_tokens: rng.index(7),
                priority: rng.index(3),
            };
            (req, t)
        })
        .collect()
}

/// Drive one random workload to completion, asserting the invariants
/// after every admission round and every step.
fn scheduler_invariants(rng: &mut Rng) -> PropResult {
    let cfg = random_config(rng);
    let arrivals = random_arrivals(rng);
    let offered: HashSet<u64> =
        arrivals.iter().map(|(r, _)| r.id).collect();
    let n_offered = offered.len();

    let mut engine = FakeKvEngine::new(2, 8, cfg.kv_cache);
    let mut sched = Scheduler::new(cfg.clone())
        .map_err(|e| format!("config rejected: {e}"))?;
    let mut retired_events: HashMap<u64, usize> = HashMap::new();
    let mut rejected_events: HashSet<u64> = HashSet::new();
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;

    // Invariants over the scheduler's visible state, checked after
    // every interaction.
    let state_ok = |sched: &Scheduler| -> PropResult {
        prop_assert(sched.live().len() <= cfg.max_batch,
                    format!("live {} > max_batch {}",
                            sched.live().len(), cfg.max_batch))?;
        for s in sched.live().iter().chain(sched.preempted()) {
            prop_assert(s.cached_len <= s.ids.len(),
                        format!("request {}: cached_len {} > len {}",
                                s.req.id, s.cached_len, s.ids.len()))?;
            if !cfg.kv_cache {
                prop_assert(s.cached_len == 0,
                            format!("request {}: cached_len {} with \
                                     KV off", s.req.id, s.cached_len))?;
            }
        }
        let warm: usize =
            sched.preempted().iter().map(|s| s.cached_len).sum();
        prop_assert(warm <= cfg.retain_cache_tokens,
                    format!("warm preempted KV {warm} over retain cap \
                             {}", cfg.retain_cache_tokens))
    };

    loop {
        loop {
            if sched.wants_offer()
                && next_arrival < arrivals.len()
                && arrivals[next_arrival].1 <= now
            {
                let (req, t) = arrivals[next_arrival].clone();
                next_arrival += 1;
                prop_assert(sched.offer(req, t),
                            "wants_offer lied: offer refused")?;
                continue;
            }
            let progressed = sched
                .admit_pending(now)
                .map_err(|e| format!("admit failed: {e}"))?;
            for e in sched.take_events() {
                match e {
                    SchedEvent::Preempted { id, cache_dropped } => {
                        engine.preempt(id, cache_dropped);
                    }
                    SchedEvent::Rejected { id } => {
                        prop_assert(rejected_events.insert(id),
                                    format!("request {id} rejected \
                                             twice"))?;
                    }
                    SchedEvent::Resumed { .. } => {}
                    SchedEvent::Retired { id } => {
                        return Err(format!(
                            "request {id}: Retired via the event \
                             stream at admission time"));
                    }
                }
            }
            state_ok(&sched)?;
            if !progressed {
                break;
            }
        }
        if sched.is_idle() {
            if next_arrival >= arrivals.len() {
                break;
            }
            now = now.max(arrivals[next_arrival].1);
            continue;
        }
        prop_assert(!sched.live().is_empty(),
                    "stalled: work pending but nothing live")?;
        prop_assert(sched.steps() < MAX_STEPS,
                    format!("runaway: {MAX_STEPS} steps without \
                             draining"))?;

        let batch = sched.microbatch();
        prop_assert(!batch.is_empty(), "empty microbatch")?;
        let tokens = sched.step_tokens(&batch);
        if cfg.mode == SchedMode::Continuous {
            prop_assert(
                tokens <= cfg.max_batch_tokens || batch.len() == 1,
                format!("step computes {tokens} > budget {} with {} \
                         sequences", cfg.max_batch_tokens, batch.len()))?;
        }
        let seqs: Vec<(u64, &[i32], usize)> = batch
            .iter()
            .map(|&i| {
                let s = &sched.live()[i];
                (s.req.id, s.ids.as_slice(), s.cached_len)
            })
            .collect();
        // The fake engine errors if the scheduler's cached-length
        // pricing disagrees with the engine-side cache map.
        let (next, rounds) = engine
            .step(&seqs)
            .map_err(|e| format!("engine/scheduler divergence: {e}"))?;
        now += 0.5 * tokens as f64 + rounds as f64;
        let retired = sched
            .complete_step(&batch, &next, now, rounds)
            .map_err(|e| format!("complete_step failed: {e}"))?;
        for id in retired {
            engine.retire(id);
            *retired_events.entry(id).or_insert(0) += 1;
        }
        state_ok(&sched)?;
    }

    // Exit accounting: no warm cache survives the drain, and every
    // offered request is in done ∪ rejected exactly once.
    prop_assert(engine.live_caches() == 0,
                format!("{} KV caches leaked past the drain",
                        engine.live_caches()))?;
    let done_ids: Vec<u64> =
        sched.done().iter().map(|s| s.req.id).collect();
    let done_set: HashSet<u64> = done_ids.iter().copied().collect();
    prop_assert(done_set.len() == done_ids.len(),
                "a request retired twice")?;
    let rej_set: HashSet<u64> =
        sched.rejected_ids().iter().copied().collect();
    prop_assert(rej_set == rejected_events,
                "rejected ids disagree with Rejected events")?;
    prop_assert(done_set.is_disjoint(&rej_set),
                "a request both retired and was rejected")?;
    prop_assert(done_set.len() + rej_set.len() == n_offered,
                format!("{} done + {} rejected != {} offered",
                        done_set.len(), rej_set.len(), n_offered))?;
    prop_assert(done_set.union(&rej_set).count() == n_offered,
                "done ∪ rejected misses an offered id")?;
    for s in sched.done() {
        let fired = retired_events.get(&s.req.id).copied().unwrap_or(0);
        let expect = usize::from(s.generated() > 0);
        prop_assert(fired == expect,
                    format!("request {}: {} retirement events, \
                             expected {expect}", s.req.id, fired))?;
    }
    Ok(())
}

fn fuzz_cases() -> usize {
    std::env::var("SCHED_FUZZ_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120)
}

#[test]
fn scheduler_invariants_hold_under_fuzz() {
    check(fuzz_cases(), scheduler_invariants);
}

/// Replay a single failing seed printed by a fuzz panic:
/// `SCHED_FUZZ_SEED=0x5eed0042 cargo test --test sched_properties
/// replay`.
#[test]
fn replay_seed_from_env() {
    if let Ok(s) = std::env::var("SCHED_FUZZ_SEED") {
        let seed = if let Some(hex) = s.strip_prefix("0x") {
            u64::from_str_radix(hex, 16).expect("hex seed")
        } else {
            s.parse().expect("decimal seed")
        };
        check_seed(seed, scheduler_invariants);
    }
}
