//! End-to-end tests over the REAL compute path: AOT artifacts → PJRT →
//! distributed dataflow. These require `make artifacts` (they skip,
//! loudly, if artifacts are missing).

use grace_moe::cluster::Topology;
use grace_moe::coordinator::OnlineCoordinator;
use grace_moe::engine::real::{place_real, profile_real, DistributedMoE,
                              FfnMode, RealModel};
use grace_moe::placement::ReplicationMode;
use grace_moe::routing::RoutingPolicy;
use grace_moe::server::{MoEServer, Request, SchedMode, ServerConfig};
use grace_moe::stats::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !d.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    if !grace_moe::runtime::pjrt::runtime_available() {
        eprintln!("SKIP: PJRT runtime unavailable (std-only xla stub) — \
                   execute-mode tests need the real xla bindings");
        return None;
    }
    Some(d)
}

#[test]
fn serve_batch_end_to_end_with_tar() {
    let Some(dir) = artifacts() else { return };
    let topo = Topology::two_by_two();
    let model = Arc::new(RealModel::load(&dir, "olmoe_tiny").unwrap());
    let trace = profile_real(&model, 1, 3).unwrap();
    let placement = Arc::new(place_real(
        &model,
        &topo,
        &trace,
        ReplicationMode::Dynamic,
        0.15,
        3,
    ));
    let mut server = MoEServer::new(
        model.clone(),
        placement,
        topo,
        RoutingPolicy::Tar,
        ServerConfig {
            max_batch: 4,
            queue_cap: 8,
            seed: 1,
            ffn_mode: FfnMode::PerExpert,
            ..ServerConfig::default()
        },
    );
    let mut rng = Rng::new(5);
    let requests: Vec<Request> = (0..3)
        .map(|i| Request {
            id: i,
            prompt: (0..12)
                .map(|_| rng.index(model.cfg.vocab) as i32)
                .collect(),
            max_new_tokens: 3,
            priority: 0,
        })
        .collect();
    let (responses, metrics) = server.serve(requests).unwrap();
    assert_eq!(responses.len(), 3);
    for r in &responses {
        assert_eq!(r.tokens.len(), 3);
        assert!(r
            .tokens
            .iter()
            .all(|&t| (t as usize) < model.cfg.vocab));
        assert!(r.latency > 0.0);
    }
    assert_eq!(metrics.generated_tokens, 9);
    assert!(metrics.throughput_tps() > 0.0);
}

#[test]
fn routing_policy_does_not_change_decoded_tokens() {
    // Losslessness at the *generation* level: greedy decode must produce
    // identical tokens regardless of which replica executed each expert.
    let Some(dir) = artifacts() else { return };
    let topo = Topology::two_by_two();
    let model = Arc::new(RealModel::load(&dir, "olmoe_tiny").unwrap());
    let trace = profile_real(&model, 1, 7).unwrap();
    let placement = Arc::new(place_real(
        &model,
        &topo,
        &trace,
        ReplicationMode::Dynamic,
        0.15,
        7,
    ));
    let mut outputs = Vec::new();
    for policy in [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                   RoutingPolicy::Tar, RoutingPolicy::LoadAware] {
        let mut server = MoEServer::new(
            model.clone(),
            placement.clone(),
            topo.clone(),
            policy,
            ServerConfig {
                max_batch: 2,
                queue_cap: 4,
                seed: 2,
                ffn_mode: FfnMode::PerExpert,
                ..ServerConfig::default()
            },
        );
        let requests = vec![Request {
            id: 0,
            prompt: (0..10).map(|i| (i * 37 % 512) as i32).collect(),
            max_new_tokens: 4,
            priority: 0,
        }];
        let (responses, _) = server.serve(requests).unwrap();
        outputs.push(responses[0].tokens.clone());
    }
    assert_eq!(outputs[0], outputs[1],
               "WRR changed decoded tokens vs Primary");
    assert_eq!(outputs[0], outputs[2],
               "TAR changed decoded tokens vs Primary");
    assert_eq!(outputs[0], outputs[3],
               "LoadAware changed decoded tokens vs Primary");
}

#[test]
fn continuous_batching_matches_static_drain_token_for_token() {
    // Determinism parity: with a fixed seed, the continuous-batching
    // scheduler must produce token-for-token identical responses to the
    // old static-drain discipline on a closed-loop workload (per-token
    // numerics are independent of batch composition, and routing replica
    // choice is lossless by construction).
    let Some(dir) = artifacts() else { return };
    let topo = Topology::two_by_two();
    let model = Arc::new(RealModel::load(&dir, "olmoe_tiny").unwrap());
    let trace = profile_real(&model, 1, 5).unwrap();
    let placement = Arc::new(place_real(
        &model,
        &topo,
        &trace,
        ReplicationMode::Dynamic,
        0.15,
        5,
    ));
    let mut rng = Rng::new(9);
    let requests: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            prompt: (0..6 + i as usize)
                .map(|_| rng.index(model.cfg.vocab) as i32)
                .collect(),
            max_new_tokens: 3,
            priority: 0,
        })
        .collect();
    let mut outputs = Vec::new();
    let mut round_counts = Vec::new();
    for mode in [SchedMode::StaticDrain, SchedMode::Continuous] {
        let mut server = MoEServer::new(
            model.clone(),
            placement.clone(),
            topo.clone(),
            RoutingPolicy::Tar,
            ServerConfig {
                max_batch: 4,
                sched: mode,
                seed: 3,
                ffn_mode: FfnMode::PerExpert,
                ..ServerConfig::default()
            },
        );
        let (responses, metrics) = server.serve(requests.clone()).unwrap();
        outputs.push(
            responses
                .iter()
                .map(|r| r.tokens.clone())
                .collect::<Vec<_>>(),
        );
        round_counts.push(metrics.dispatch_rounds);
        assert_eq!(metrics.generated_tokens, 12);
        assert!(!metrics.ttft.is_empty());
    }
    assert_eq!(outputs[0], outputs[1],
               "continuous batching changed decoded tokens");
    assert!(
        round_counts[1] <= round_counts[0],
        "batched decode must not issue more dispatch rounds: \
         continuous {} vs static {}",
        round_counts[1],
        round_counts[0]
    );
}

#[test]
fn kv_cached_serving_matches_recompute_token_for_token() {
    // The headline KV-cache invariant on the REAL compute path: serving
    // with per-sequence KV caches (`kv_cache: true`, the default) must
    // produce token-for-token identical responses to full-recompute
    // decode (`--kv-cache off`, the parity oracle), while issuing
    // strictly fewer MoE dispatch rounds and pricing cached prefixes
    // into `ServeMetrics::cached_tokens`.
    let Some(dir) = artifacts() else { return };
    let topo = Topology::two_by_two();
    let model = Arc::new(RealModel::load(&dir, "olmoe_tiny").unwrap());
    let trace = profile_real(&model, 1, 5).unwrap();
    let placement = Arc::new(place_real(
        &model,
        &topo,
        &trace,
        ReplicationMode::Dynamic,
        0.15,
        5,
    ));
    let mut rng = Rng::new(21);
    let requests: Vec<Request> = (0..4)
        .map(|i| Request {
            id: i,
            prompt: (0..6 + i as usize)
                .map(|_| rng.index(model.cfg.vocab) as i32)
                .collect(),
            max_new_tokens: 4,
            priority: 0,
        })
        .collect();
    let mut outputs = Vec::new();
    let mut all_metrics = Vec::new();
    for kv in [false, true] {
        let mut server = MoEServer::new(
            model.clone(),
            placement.clone(),
            topo.clone(),
            RoutingPolicy::Tar,
            ServerConfig {
                max_batch: 4,
                kv_cache: kv,
                seed: 3,
                ffn_mode: FfnMode::PerExpert,
                ..ServerConfig::default()
            },
        );
        let (responses, metrics) = server.serve(requests.clone()).unwrap();
        outputs.push(
            responses
                .iter()
                .map(|r| r.tokens.clone())
                .collect::<Vec<_>>(),
        );
        assert_eq!(metrics.generated_tokens, 16);
        all_metrics.push(metrics);
    }
    assert_eq!(outputs[0], outputs[1],
               "KV-cached decode changed decoded tokens vs recompute");
    let (re, kv) = (&all_metrics[0], &all_metrics[1]);
    assert_eq!(re.cached_tokens, 0, "recompute must not hit a cache");
    assert!(kv.cached_tokens > 0, "KV path never hit the cache");
    assert!(
        kv.computed_tokens < re.computed_tokens,
        "KV decode must compute fewer tokens: {} vs {}",
        kv.computed_tokens,
        re.computed_tokens
    );
    assert!(
        kv.dispatch_rounds < re.dispatch_rounds,
        "KV decode must issue fewer dispatch rounds: {} vs {}",
        kv.dispatch_rounds,
        re.dispatch_rounds
    );
}

#[test]
fn dsv2_variant_also_serves() {
    // Second architecture (top-6): the whole stack is variant-generic.
    let Some(dir) = artifacts() else { return };
    let topo = Topology::two_by_two();
    let model = Arc::new(RealModel::load(&dir, "dsv2_tiny").unwrap());
    assert_eq!(model.cfg.top_k, 6);
    let trace = profile_real(&model, 1, 11).unwrap();
    let placement = Arc::new(place_real(
        &model,
        &topo,
        &trace,
        ReplicationMode::Dynamic,
        0.15,
        11,
    ));
    let coord = OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
    let mut dist = DistributedMoE::new(model.clone(), placement.clone(),
                                       &coord, FfnMode::GroupedPallas);
    let c = model.cfg.clone();
    let mut rng = Rng::new(13);
    let x: Vec<f32> = (0..c.tile_t * c.hidden)
        .map(|_| rng.gaussian() as f32 * 0.3)
        .collect();
    let want = model.moe_layer_oracle(&x, 1).unwrap();
    let run = dist.moe_layer(&x, 1, &(|t| t % 4), &mut rng).unwrap();
    let max_err = run
        .y
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-4, "dsv2 losslessness: {max_err}");
}
