//! Replan stability tests — the acceptance bar of the epoch-based
//! online re-planner:
//!
//! * **stationary parity** — replaying the profiling trace as serving
//!   traffic, every epoch's delta must be empty and the re-planned run's
//!   metrics must be *bit-identical* to static GRACE (the feedback loop
//!   observes, never perturbs);
//! * **rotating-hot-expert win** — on a fixture whose hot expert moves
//!   mid-trace, the re-planned run must strictly reduce the post-drift
//!   max per-GPU load share vs static GRACE, with the migration bytes
//!   accounted in the simulated latency model.

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::coordinator::OnlineCoordinator;
use grace_moe::engine::sim::{build_placement, simulate_rounds, SimConfig};
use grace_moe::linalg::Matrix;
use grace_moe::placement::{LayerPlacement, Placement, ReplicationMode};
use grace_moe::profile::LayerProfile;
use grace_moe::replan::{self, CostParams, ReplanConfig, Replanner};
use grace_moe::routing::{Assignment, RoutingPolicy};
use grace_moe::server::sched::{simulate_serve, SchedConfig, SchedMode};
use grace_moe::server::{even_src, Request, Response};
use grace_moe::stats::Rng;
use grace_moe::trace::{GateTrace, LayerTrace, Profile, TraceGen};

fn replan_cfg(payback: f64) -> ReplanConfig {
    ReplanConfig {
        epoch_rounds: 2,
        min_drift: 0.05,
        payback,
        ..ReplanConfig::default()
    }
}

#[test]
fn stationary_replay_is_bit_identical_to_static_grace() {
    // Serving rounds replay the profiling trace itself: measured loads
    // equal the profiled loads exactly, so the recomputed Eq.-3 decision
    // is structurally the active one every epoch and the re-planner must
    // be a pure observer.
    let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
    let mut cfg = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload { batch: 32, prefill: 16, decode: 4 },
    );
    cfg.serve_profile = Profile::Math;
    cfg.placement_profile = Profile::Math;
    cfg.profile_tokens = 512;

    let sys = SystemSpec::grace(0.15);
    let dyn_sys = SystemSpec::grace_dyn(0.15);
    let placement = build_placement(&sys, &cfg);
    // The exact trace the placement was profiled on, replayed 6 times.
    let profile_trace = TraceGen {
        experts: cfg.model.experts,
        top_k: cfg.model.top_k,
        layers: cfg.model.moe_layers,
        profile: cfg.placement_profile,
        seed: cfg.seed,
    }
    .generate(cfg.profile_tokens);
    let rounds: Vec<GateTrace> =
        (0..6).map(|_| profile_trace.clone()).collect();

    let (ms, rs) =
        simulate_rounds(&sys, &cfg, &placement, &rounds, None);
    // alpha = 1.0 makes the EWMA a pure per-round replacement, so the
    // measured loads equal the profiled counts *exactly* (no ulp drift
    // across folds) and the structural no-op is airtight.
    let rc = ReplanConfig { alpha: 1.0, ..replan_cfg(0.0) };
    let (md, rd) =
        simulate_rounds(&dyn_sys, &cfg, &placement, &rounds, Some(rc));

    // Epoch deltas empty: nothing applied, nothing migrated.
    assert_eq!(rd.applied, 0, "stationary epochs must be empty");
    assert_eq!(rd.migration_bytes, 0.0);
    assert_eq!(md.replans, 0);
    assert_eq!(md.migration_bytes, 0.0);

    // Batched dispatch output bit-identical to the static path.
    assert_eq!(ms.e2e_time, md.e2e_time);
    assert_eq!(ms.moe_layer_time, md.moe_layer_time);
    assert_eq!(ms.a2a_time, md.a2a_time);
    assert_eq!(ms.cross_bytes, md.cross_bytes);
    assert_eq!(ms.intra_bytes, md.intra_bytes);
    assert_eq!(ms.idle_time, md.idle_time);
    assert_eq!(ms.layer_load_std, md.layer_load_std);
    assert_eq!(ms.launches, md.launches);
    assert_eq!(ms.tokens, md.tokens);
    assert_eq!(rs.copies_rounds, rd.copies_rounds,
               "per-round routed copies must match exactly");
}

/// One hand-built serving round: `counts[e]` tokens select expert `e`,
/// laid out contiguously so `even_src` spreads sources across GPUs.
fn round_of(counts: &[usize]) -> GateTrace {
    let tokens: Vec<Vec<u16>> = counts
        .iter()
        .enumerate()
        .flat_map(|(e, &c)| vec![vec![e as u16]; c])
        .collect();
    GateTrace {
        layers: vec![LayerTrace { experts: counts.len(), top_k: 1, tokens }],
    }
}

/// 4 experts / 4 GPUs / 1 node: expert `e` primary on GPU `e`, dynamic
/// replication computed from `loads`.
fn fixture_placement(loads: Vec<f64>) -> Placement {
    let profile = LayerProfile {
        affinity: Matrix::zeros(loads.len(), loads.len()),
        load: loads,
        tokens: 400,
    };
    let lp = LayerPlacement::build(
        &profile,
        vec![vec![0], vec![1], vec![2], vec![3]],
        ReplicationMode::Dynamic,
    );
    Placement { layers: vec![lp], experts: 4, num_gpus: 4 }
}

#[test]
fn rotating_hot_expert_replan_beats_static_and_accounts_migration() {
    // Offline profile: expert 0 hot (replicated). Mid-trace the load
    // rotates onto expert 3, whose only instance is GPU 3 — the static
    // system funnels ~70% of every post-drift round onto one GPU, while
    // the re-planner replicates expert 3 and spreads it.
    let topo = Topology::paper_testbed(1, 4);
    let model = ModelSpec {
        name: "tiny4",
        tiny_variant: "",
        experts: 4,
        top_k: 1,
        moe_layers: 1,
        hidden: 64,
        ffn: 64,
        act_bytes: 2,
    };
    let mut cfg =
        SimConfig::new(model, topo, Workload { batch: 4, prefill: 100,
                                               decode: 0 });
    cfg.max_chunk = 400;

    let placement = fixture_placement(vec![280.0, 60.0, 40.0, 20.0]);
    assert_eq!(placement.layers[0].replication.hot_experts, vec![0]);

    let base = [280usize, 60, 40, 20];
    let drift = [20usize, 40, 60, 280];
    let drift_at = 2usize;
    let rounds: Vec<GateTrace> = (0..14)
        .map(|i| round_of(if i < drift_at { &base } else { &drift }))
        .collect();

    let sys = SystemSpec::grace(0.15);
    let dyn_sys = SystemSpec::grace_dyn(0.15);
    // payback 0: the fixture is tiny, so the compute-seconds at stake
    // are microscopic against real A100 expert weights — the drift gate
    // alone decides (the cost gate has its own unit test).
    let (ms, rs) =
        simulate_rounds(&sys, &cfg, &placement, &rounds, None);
    let (md, rd) = simulate_rounds(&dyn_sys, &cfg, &placement, &rounds,
                                   Some(replan_cfg(0.0)));

    let static_share = rs.max_load_share(drift_at);
    let dyn_share = rd.max_load_share(drift_at);
    assert!(static_share > 0.65,
            "fixture must overload one GPU statically: {static_share}");
    assert!(
        dyn_share < static_share,
        "replanned post-drift max share {dyn_share} !< static \
         {static_share}"
    );

    // The swap happened and its migration is visible in the metrics:
    // bytes accounted and latency charged through the comm model.
    assert!(rd.applied >= 1, "no epoch delta applied");
    assert!(md.replans >= 1);
    assert!(md.migration_bytes > 0.0);
    assert_eq!(md.migration_bytes, rd.migration_bytes);
    assert!(ms.migration_bytes == 0.0 && ms.replans == 0);
    // Migration traffic flows over real links → some bytes show up in
    // the traffic accounting beyond the static run's identical rounds
    // would… at minimum the e2e time includes a positive migration term.
    assert!(md.e2e_time.is_finite() && md.e2e_time > 0.0);
}

#[test]
fn scheduler_step_boundary_replan_is_a_pure_observer_when_stationary() {
    // PR-5 extension: the continuous-batching scheduler re-homed the
    // epoch tick from "between batch drains" to the decode-step
    // boundary. Same invariant, new home: on stationary traffic every
    // tick is a structural no-op, so serving with the re-planner
    // attached is routing-identical (and token-identical) to serving
    // without it. Exercised engine-free: a fake decode whose dispatch
    // round replays the profiled distribution exactly, driven through
    // the real Dispatcher + OnlineCoordinator + Replanner.
    let topo = Topology::paper_testbed(1, 4);
    let placement = fixture_placement(vec![280.0, 60.0, 40.0, 20.0]);
    let counts = [280usize, 60, 40, 20];

    let run = |with_replan: bool| {
        let mut coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        if with_replan {
            let rc = ReplanConfig {
                epoch_rounds: 2,
                min_drift: 0.05,
                payback: 0.0,
                alpha: 1.0,
            };
            coord = coord.with_replanner(Replanner::new(
                topo.clone(),
                rc,
                CostParams { expert_bytes: 1e6,
                             moe_s_per_assignment: 1e-6 },
            ));
        }
        let mut dispatcher = coord.dispatcher(4096.0);
        let mut rng = Rng::new(42);
        let mut active = placement.clone();
        let mut applied = 0usize;
        let mut copies_rounds: Vec<Vec<usize>> = Vec::new();

        let arrivals: Vec<(Request, f64)> = (0..6)
            .map(|id| {
                (Request {
                    id,
                    prompt: vec![1, 2, 3, 4],
                    max_new_tokens: 3,
                    priority: 0,
                }, 0.0)
            })
            .collect();
        let (responses, metrics) = simulate_serve(
            SchedConfig {
                mode: SchedMode::Continuous,
                max_batch: 3,
                max_batch_tokens: 64,
                ctx: 16,
                kv_cache: false,
                ..SchedConfig::default()
            },
            arrivals,
            |seqs| {
                // One stationary dispatch round per step: serving
                // traffic replays the profiled load histogram exactly.
                let total: usize = counts.iter().sum();
                let mut batch = Vec::with_capacity(total);
                let mut t = 0usize;
                for (e, &c) in counts.iter().enumerate() {
                    for _ in 0..c {
                        batch.push(Assignment {
                            token: t,
                            expert: e,
                            src: even_src(t, total, 4),
                        });
                        t += 1;
                    }
                }
                let plan = {
                    let lp = &active.layers[0];
                    let plan = dispatcher.dispatch(lp, 0, &batch, &mut rng);
                    coord.observe(0, lp, &plan);
                    plan
                };
                copies_rounds.push(plan.copies_per_gpu().to_vec());
                // Step boundary — the only place the epoch may tick.
                let delta = coord.epoch_tick(&active);
                if !delta.is_empty() {
                    active = replan::apply_delta(&active, &delta);
                    applied += 1;
                }
                let next: Vec<i32> = seqs
                    .iter()
                    .map(|(id, ids, _)| *id as i32 + ids.len() as i32)
                    .collect();
                Ok((next, 1))
            },
            |_, _| 1.0,
        )
        .unwrap();
        (responses, metrics, copies_rounds, applied)
    };

    let (r_off, m_off, c_off, a_off) = run(false);
    let (r_on, m_on, c_on, a_on) = run(true);
    assert_eq!(a_off, 0, "no replanner, no deltas");
    assert_eq!(a_on, 0,
               "stationary epochs must be empty under the scheduler");
    assert!(m_on.steps >= 4, "needs several epochs: {} steps", m_on.steps);
    assert_eq!(c_off, c_on, "the re-planner perturbed routing");
    assert_eq!(m_off.steps, m_on.steps);
    assert_eq!(m_off.dispatch_rounds, m_on.dispatch_rounds);
    let toks = |rs: &[Response]| {
        rs.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(toks(&r_off), toks(&r_on),
               "responses must be token-identical");
}

#[test]
fn replanned_run_is_deterministic() {
    let placement = fixture_placement(vec![280.0, 60.0, 40.0, 20.0]);
    let topo = Topology::paper_testbed(1, 4);
    let model = ModelSpec {
        name: "tiny4",
        tiny_variant: "",
        experts: 4,
        top_k: 1,
        moe_layers: 1,
        hidden: 64,
        ffn: 64,
        act_bytes: 2,
    };
    let cfg = SimConfig::new(model, topo,
                             Workload { batch: 4, prefill: 100,
                                        decode: 0 });
    let rounds: Vec<GateTrace> =
        (0..8).map(|_| round_of(&[20, 40, 60, 280])).collect();
    let dyn_sys = SystemSpec::grace_dyn(0.15);
    let run = || {
        simulate_rounds(&dyn_sys, &cfg, &placement, &rounds,
                        Some(replan_cfg(0.0)))
    };
    let (a, ra) = run();
    let (b, rb) = run();
    assert_eq!(a.e2e_time, b.e2e_time);
    assert_eq!(a.migration_bytes, b.migration_bytes);
    assert_eq!(ra.applied, rb.applied);
    assert_eq!(ra.copies_rounds, rb.copies_rounds);
}
