//! Cross-module integration tests: the full offline→online pipeline over
//! the simulator, exercised end to end with paper-shaped assertions.

use grace_moe::baselines::{GroupingStrategy, SystemSpec};
use grace_moe::cluster::{GpuId, Topology};
use grace_moe::comm::model::{self, CommModel, CommReport};
use grace_moe::comm::traffic::{self, Dispatch};
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::coordinator::Coordinator;
use grace_moe::engine::sim::{build_placement, simulate,
                             simulate_with_placement, SimConfig,
                             ROUTE_DECISION_COST};
use grace_moe::grouping::is_partition;
use grace_moe::metrics::RunMetrics;
use grace_moe::placement::{LayerPlacement, Placement, ReplicationMode};
use grace_moe::profile::ModelProfile;
use grace_moe::routing::RoutingPolicy;
use grace_moe::stats::dist::weighted_choice;
use grace_moe::stats::{Rng, Summary};
use grace_moe::testutil::{check, prop_assert};
use grace_moe::trace::{GateTrace, Profile, TraceGen};

fn small(model: ModelSpec, topo: Topology) -> SimConfig {
    let model = ModelSpec { moe_layers: 3, ..model };
    let mut cfg = SimConfig::new(
        model,
        topo,
        Workload { batch: 64, prefill: 16, decode: 4 },
    );
    cfg.profile_tokens = 512;
    cfg.max_chunk = 1024;
    cfg
}

#[test]
fn full_pipeline_all_models_all_clusters() {
    for model in ModelSpec::all() {
        for topo in [Topology::two_by_two(), Topology::two_by_four()] {
            let cfg = small(model.clone(), topo);
            let m = simulate(&SystemSpec::grace(0.15), &cfg);
            assert!(m.e2e_time > 0.0, "{}: zero e2e", model.name);
            assert!(m.moe_layer_time > 0.0);
            assert!(m.a2a_time > 0.0);
            assert_eq!(m.layer_load_std.len(), 3 * 2);
        }
    }
}

#[test]
fn grace_placement_respects_memory_budget() {
    let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    let p = build_placement(&SystemSpec::grace(0.15), &cfg);
    // full-scale OLMoE expert ≈ 12.6 MB bf16; must fit easily in 80 GB
    p.check_memory(&cfg.topo, cfg.model.expert_bytes())
        .expect("placement must fit HBM");
    // replication is sparse (paper: "only a small subset of heavily
    // skewed experts per layer")
    assert!(p.replication_overhead() < 0.5,
            "overhead {}", p.replication_overhead());
}

#[test]
fn every_fig4_system_runs_and_orders_sanely() {
    let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    let systems = SystemSpec::fig4_systems(0.15);
    let runs: Vec<_> =
        systems.iter().map(|s| simulate(s, &cfg)).collect();
    // GRACE (last) must beat the vanilla baseline (first) clearly.
    let vanilla = &runs[0];
    let grace = runs.last().unwrap();
    assert!(
        grace.e2e_time < vanilla.e2e_time,
        "grace {} !< vanilla {}",
        grace.e2e_time,
        vanilla.e2e_time
    );
    // every system processes the same token count
    for m in &runs {
        assert_eq!(m.tokens, cfg.workload.total_tokens());
    }
}

#[test]
fn table1_ladder_reproduces_paper_directions() {
    // The qualitative Table-1 signature, averaged over the three models.
    let mut avg: Vec<grace_moe::metrics::RunMetrics> =
        (0..6).map(|_| Default::default()).collect();
    for model in ModelSpec::all() {
        let mut cfg = small(model, Topology::two_by_two());
        cfg.serve_profile = Profile::Math;
        cfg.placement_profile = Profile::Math;
        let ladder = SystemSpec::table1_ladder(0.15);
        for (acc, sys) in avg.iter_mut().zip(&ladder) {
            acc.accumulate(&simulate(sys, &cfg));
        }
    }
    let (occult, occult_hsc, hg_hsc, _fr, dr_wrr, dr_tar) =
        (&avg[0], &avg[1], &avg[2], &avg[3], &avg[4], &avg[5]);
    // RQ1: HSC cuts A2A time and cross traffic; shifts to intra.
    assert!(occult_hsc.a2a_time < occult.a2a_time);
    assert!(occult_hsc.cross_bytes < occult.cross_bytes);
    assert!(occult_hsc.intra_bytes > occult.intra_bytes);
    // HG cuts cross traffic further…
    assert!(hg_hsc.cross_bytes < occult_hsc.cross_bytes);
    // RQ2: …but worsens load balance; DR+WRR recovers it.
    assert!(hg_hsc.mean_load_std() > occult_hsc.mean_load_std());
    assert!(dr_wrr.mean_load_std() < hg_hsc.mean_load_std());
    assert!(dr_wrr.idle_time < hg_hsc.idle_time);
    // RQ3: TAR trims the traffic DR+WRR added.
    assert!(dr_tar.cross_bytes <= dr_wrr.cross_bytes);
    // Full ladder beats Occult end-to-end.
    assert!(dr_tar.e2e_time < occult.e2e_time);
}

#[test]
fn cross_dataset_transfer_stays_competitive() {
    // Fig. 6 shape at small scale: transferred placements lose little vs
    // in-domain and stay ahead of Occult.
    let sys = SystemSpec::grace(0.15);
    let mk = |serve, place| {
        let mut cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
        cfg.serve_profile = serve;
        cfg.placement_profile = place;
        cfg
    };
    for &target in &Profile::ALL {
        let indomain =
            simulate(&sys, &mk(target, target)).e2e_time;
        let occult =
            simulate(&SystemSpec::occult(), &mk(target, target)).e2e_time;
        for &src in &Profile::ALL {
            if src == target {
                continue;
            }
            let cfg = mk(target, src);
            let placement = build_placement(&sys, &cfg);
            let transferred =
                simulate_with_placement(&sys, &cfg, &placement).e2e_time;
            assert!(
                transferred < indomain * 1.25,
                "{src:?}→{target:?}: {transferred} vs in-domain \
                 {indomain}"
            );
            assert!(
                transferred < occult,
                "{src:?}→{target:?}: transferred {transferred} !< \
                 occult {occult}"
            );
        }
    }
}

#[test]
fn property_pipeline_is_total_over_random_configs() {
    check(15, |rng| {
        let models = ModelSpec::all();
        let model = models[rng.index(3)].clone();
        let topo = Topology::paper_testbed(1 + rng.index(3),
                                           1 + rng.index(4));
        if topo.num_gpus() < 2 {
            return Ok(());
        }
        let mut cfg = small(model, topo);
        cfg.seed = rng.next_u64();
        cfg.workload = Workload {
            batch: 8 + rng.index(64),
            prefill: 1 + rng.index(32),
            decode: rng.index(8),
        };
        let sys = match rng.index(4) {
            0 => SystemSpec::grace(0.05 + rng.f64() * 0.5),
            1 => SystemSpec::occult(),
            2 => SystemSpec::c2r(),
            _ => SystemSpec {
                comm: CommModel::StagedHierarchical,
                ..SystemSpec::occult()
            },
        };
        let m = simulate(&sys, &cfg);
        prop_assert(m.e2e_time.is_finite() && m.e2e_time > 0.0,
                    "bad e2e")?;
        prop_assert(m.cross_bytes >= 0.0 && m.intra_bytes >= 0.0,
                    "negative traffic")?;
        prop_assert(m.idle_time >= -1e-9, "negative idle")
    });
}

#[test]
fn property_groupings_stay_partitions_through_placement() {
    check(10, |rng| {
        let cfg = small(ModelSpec::olmoe(), Topology::two_by_four());
        let strategies = [
            GroupingStrategy::Sequential,
            GroupingStrategy::Uniform,
            GroupingStrategy::Hierarchical { r: rng.f64() },
            GroupingStrategy::FullyNonUniform,
        ];
        let sys = SystemSpec {
            grouping: strategies[rng.index(4)],
            replication: [ReplicationMode::None, ReplicationMode::Fixed,
                          ReplicationMode::Dynamic][rng.index(3)],
            routing: [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                      RoutingPolicy::Tar, RoutingPolicy::LoadAware]
                [rng.index(4)],
            ..SystemSpec::occult()
        };
        let p = build_placement(&sys, &cfg);
        for lp in &p.layers {
            prop_assert(is_partition(&lp.groups, p.experts),
                        "groups not a partition")?;
            for (e, inst) in lp.instances.iter().enumerate() {
                prop_assert(inst[0] == lp.primary[e], "primary first")?;
            }
        }
        Ok(())
    });
}

#[test]
fn coordinator_pipeline_matches_hand_wired_path() {
    // The engines now assemble the pipeline exclusively through the L3
    // Coordinator; this pins the refactor down: the coordinator-built
    // placement and run metrics must be *identical* to what the
    // previously hand-wired offline phase (trace generation → profiling →
    // Placement::build with the per-system grouping closure) produced.
    for sys in [SystemSpec::grace(0.15), SystemSpec::occult()] {
        let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());

        // Hand-wired path (verbatim pre-coordinator wiring, including the
        // grouping-RNG seed derivation).
        let profiling = TraceGen {
            experts: cfg.model.experts,
            top_k: cfg.model.top_k,
            layers: cfg.model.moe_layers,
            profile: cfg.placement_profile,
            seed: cfg.seed,
        }
        .generate(cfg.profile_tokens);
        let profile = ModelProfile::from_trace(&profiling);
        let mut rng = Rng::new(cfg.seed ^ 0x9A0C);
        let hand = Placement::build(&profile, sys.replication, |lp| {
            sys.grouping.build(lp, &cfg.topo, &mut rng)
        });

        // Coordinator path (what the sim engine does today).
        let coord = Coordinator::for_system(&sys, &cfg.topo, cfg.seed);
        let coordinated = coord.offline_synthetic(
            &cfg.model,
            cfg.placement_profile,
            cfg.profile_tokens,
        );

        assert_eq!(hand.layers.len(), coordinated.layers.len());
        for (h, c) in hand.layers.iter().zip(&coordinated.layers) {
            assert_eq!(h.groups, c.groups, "{}: groups differ", sys.name);
            assert_eq!(h.primary, c.primary);
            assert_eq!(h.instances, c.instances);
            assert_eq!(h.replication, c.replication);
            assert_eq!(h.polling, c.polling);
        }

        // And the online phase over both placements must be
        // metric-identical, bit for bit.
        let a = simulate_with_placement(&sys, &cfg, &hand);
        let b = simulate_with_placement(&sys, &cfg, &coordinated);
        assert_eq!(a.e2e_time, b.e2e_time, "{}", sys.name);
        assert_eq!(a.moe_layer_time, b.moe_layer_time);
        assert_eq!(a.a2a_time, b.a2a_time);
        assert_eq!(a.cross_bytes, b.cross_bytes);
        assert_eq!(a.intra_bytes, b.intra_bytes);
        assert_eq!(a.idle_time, b.idle_time);
        assert_eq!(a.layer_load_std, b.layer_load_std);
        assert_eq!(a.launches, b.launches);
        assert_eq!(a.tokens, b.tokens);
    }
}

// ---------------------------------------------------------------------------
// Scalar-reference parity: a verbatim replica of the pre-refactor online
// phase (per-token `Router::route` walk, per-token Vec<Dispatch> fed to
// the traffic builders) that the batched DispatchPlan path must match
// bit for bit for the frozen-weight policies (Primary / Wrr / Tar).
// C2R-style pruning is excluded on purpose: the batched engine draws its
// prune coins while assembling the batch, which reorders the RNG stream
// relative to the old interleaved walk.
// ---------------------------------------------------------------------------

/// Pre-refactor `Router::wrr`, including its (biased) `candidates[0]`
/// zero-weight fallback — the reference must reproduce the old stream
/// exactly, and the fallback is unreachable under Eq.-4 weights anyway.
fn scalar_wrr(lp: &LayerPlacement, candidates: &[GpuId], rng: &mut Rng)
              -> GpuId {
    let weights: Vec<f64> =
        candidates.iter().map(|&g| lp.polling[g]).collect();
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return candidates[0];
    }
    candidates[weighted_choice(rng, &weights)]
}

/// Pre-refactor `Router::route`.
fn scalar_route(lp: &LayerPlacement, topo: &Topology,
                policy: RoutingPolicy, src: GpuId, expert: usize,
                rng: &mut Rng) -> GpuId {
    let instances = &lp.instances[expert];
    if instances.len() == 1 {
        return instances[0];
    }
    match policy {
        RoutingPolicy::Primary => instances[0],
        RoutingPolicy::Wrr => scalar_wrr(lp, instances, rng),
        RoutingPolicy::Tar => {
            if instances.contains(&src) {
                return src;
            }
            let node = topo.node_of(src);
            let local: Vec<GpuId> = instances
                .iter()
                .copied()
                .filter(|&g| topo.node_of(g) == node)
                .collect();
            if !local.is_empty() {
                return scalar_wrr(lp, &local, rng);
            }
            scalar_wrr(lp, instances, rng)
        }
        RoutingPolicy::LoadAware => {
            unreachable!("scalar reference covers frozen-weight policies")
        }
    }
}

/// Pre-refactor `comm_round` (token-major Vec<Dispatch> input).
fn scalar_comm_round(sys: &SystemSpec, topo: &Topology,
                     dispatches: &[Dispatch], spec: &ModelSpec,
                     overlap: f64, rng: &mut Rng) -> CommReport {
    let tb = spec.token_bytes();
    match sys.comm {
        CommModel::Flat => {
            let m = if sys.dedup_flat {
                traffic::per_gpu_dedup(dispatches, topo.num_gpus(), tb)
            } else {
                traffic::per_copy(dispatches, topo.num_gpus(), tb)
            };
            model::flat_all_to_all(&m, topo, rng)
        }
        CommModel::StagedHierarchical => {
            let ts = traffic::two_stage(dispatches, topo, tb);
            model::staged_hierarchical(&ts, topo, rng)
        }
        CommModel::Hsc => {
            let ts = traffic::two_stage(dispatches, topo, tb);
            model::hsc(&ts, topo, overlap, rng)
        }
    }
}

/// Pre-refactor `sim_phase`: the scalar per-token routing loop.
fn scalar_phase(sys: &SystemSpec, cfg: &SimConfig, placement: &Placement,
                trace: &GateTrace, scale: f64, rng: &mut Rng,
                metrics: &mut RunMetrics) {
    let topo = &cfg.topo;
    let n_gpus = topo.num_gpus();
    let spec = &cfg.model;
    let chunk = trace.num_tokens();

    let mut dispatches: Vec<Dispatch> = Vec::with_capacity(chunk);
    let mut copies = vec![0.0f64; n_gpus];

    for (layer_idx, layer) in trace.layers.iter().enumerate() {
        let lp = &placement.layers[layer_idx];
        dispatches.clear();
        copies.iter_mut().for_each(|c| *c = 0.0);

        for (t, experts) in layer.tokens.iter().enumerate() {
            let src = t * n_gpus / chunk;
            let mut dsts = Vec::with_capacity(experts.len());
            for &e in experts {
                let e = e as usize;
                if sys.prune_remote > 0.0 {
                    let primary = lp.primary[e];
                    if !topo.same_node(src, primary)
                        && rng.chance(sys.prune_remote)
                    {
                        continue;
                    }
                }
                let dst =
                    scalar_route(lp, topo, sys.routing, src, e, rng);
                copies[dst] += 1.0;
                dsts.push(dst);
            }
            dispatches.push(Dispatch { src, dsts });
        }

        let overlap = if sys.comm == CommModel::Hsc {
            chunk as f64 * ROUTE_DECISION_COST / n_gpus as f64
        } else {
            0.0
        };
        let mut comm =
            scalar_comm_round(sys, topo, &dispatches, spec, overlap, rng);
        let combine =
            scalar_comm_round(sys, topo, &dispatches, spec, 0.0, rng);
        comm.accumulate(&combine);

        let mut t_max = 0.0f64;
        let mut t_sum = 0.0f64;
        for &c in &copies {
            let t = cfg.gpu.moe_time(spec, c) / sys.compute_eff
                + cfg.gpu.layer_overhead;
            t_max = t_max.max(t);
            t_sum += t;
        }
        let idle = n_gpus as f64 * t_max - t_sum;

        metrics.a2a_time += comm.time * sys.comm_eff * scale;
        metrics.cross_bytes += comm.cross_bytes * scale;
        metrics.intra_bytes += comm.intra_bytes * scale;
        metrics.launches += comm.launches;
        metrics.idle_time += idle * scale;
        metrics
            .layer_load_std
            .push(Summary::of(&copies).std() * scale);
        let layer_time = comm.time * sys.comm_eff + t_max;
        metrics.moe_layer_time += layer_time * scale;
        let dense =
            cfg.gpu.dense_time(spec, chunk as f64 / n_gpus as f64)
                + cfg.gpu.layer_overhead;
        metrics.e2e_time += (layer_time + dense) * scale;
    }
}

/// Pre-refactor `simulate_with_placement` (identical chunking and serve-
/// trace seed derivation).
fn scalar_simulate(sys: &SystemSpec, cfg: &SimConfig,
                   placement: &Placement) -> RunMetrics {
    let serve = |tokens: usize, tag: u64| {
        TraceGen {
            experts: cfg.model.experts,
            top_k: cfg.model.top_k,
            layers: cfg.model.moe_layers,
            profile: cfg.serve_profile,
            seed: cfg.seed.wrapping_mul(0x1009).wrapping_add(tag),
        }
        .generate(tokens)
    };
    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let mut metrics = RunMetrics::default();
    let prefill_tokens = cfg.workload.batch * cfg.workload.prefill;
    let chunk = prefill_tokens.min(cfg.max_chunk);
    if chunk > 0 {
        let scale = prefill_tokens as f64 / chunk as f64;
        scalar_phase(sys, cfg, placement, &serve(chunk, 1), scale,
                     &mut rng, &mut metrics);
    }
    let dchunk = cfg.workload.batch.min(cfg.max_chunk);
    if dchunk > 0 && cfg.workload.decode > 0 {
        let scale = cfg.workload.decode as f64
            * cfg.workload.batch as f64
            / dchunk as f64;
        scalar_phase(sys, cfg, placement, &serve(dchunk, 2), scale,
                     &mut rng, &mut metrics);
    }
    metrics.tokens = cfg.workload.total_tokens();
    metrics
}

#[test]
fn batched_dispatch_matches_scalar_routing_bit_for_bit() {
    // Primary / Wrr / Tar across all three collectives: the batched
    // DispatchPlan path must reproduce the pre-refactor scalar path's
    // metrics exactly (same RNG stream, same summation order).
    let ladder = SystemSpec::table1_ladder(0.15);
    let systems = vec![
        SystemSpec::vanilla(),               // Primary, flat, no dedup
        SystemSpec::occult(),                // Primary, flat, dedup
        SystemSpec {
            name: "occult+staged",
            comm: CommModel::StagedHierarchical,
            ..SystemSpec::occult()
        },                                   // Primary, staged
        ladder[4].clone(),                   // +dr+wrr: Wrr on HSC
        SystemSpec::grace(0.15),             // Tar on HSC
    ];
    for sys in systems {
        let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
        let placement = build_placement(&sys, &cfg);
        let scalar = scalar_simulate(&sys, &cfg, &placement);
        let batched = simulate_with_placement(&sys, &cfg, &placement);
        assert_eq!(scalar.e2e_time, batched.e2e_time, "{}", sys.name);
        assert_eq!(scalar.moe_layer_time, batched.moe_layer_time,
                   "{}", sys.name);
        assert_eq!(scalar.a2a_time, batched.a2a_time, "{}", sys.name);
        assert_eq!(scalar.cross_bytes, batched.cross_bytes,
                   "{}", sys.name);
        assert_eq!(scalar.intra_bytes, batched.intra_bytes,
                   "{}", sys.name);
        assert_eq!(scalar.idle_time, batched.idle_time, "{}", sys.name);
        assert_eq!(scalar.layer_load_std, batched.layer_load_std,
                   "{}", sys.name);
        assert_eq!(scalar.launches, batched.launches, "{}", sys.name);
        assert_eq!(scalar.tokens, batched.tokens, "{}", sys.name);
    }
}

#[test]
fn load_aware_pipeline_runs_end_to_end() {
    // The online load-predictive router through the whole sim pipeline:
    // sane, deterministic metrics on every model (the statistical
    // max-load-share claim is pinned at the policy level in
    // routing::tests::load_aware_reduces_max_load_share_vs_static_wrr).
    for model in ModelSpec::all() {
        let mut cfg = small(model, Topology::two_by_two());
        cfg.serve_profile = Profile::Math;
        cfg.placement_profile = Profile::Text; // drifted vs serving
        let sys = SystemSpec::grace_load_aware(0.15);
        let a = simulate(&sys, &cfg);
        let b = simulate(&sys, &cfg);
        assert!(a.e2e_time > 0.0 && a.e2e_time.is_finite());
        assert!(a.idle_time >= -1e-9);
        assert_eq!(a.e2e_time, b.e2e_time, "deterministic");
        assert_eq!(a.layer_load_std, b.layer_load_std);
    }
}

#[test]
fn decode_only_and_prefill_only_workloads() {
    let mut cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    cfg.workload = Workload { batch: 16, prefill: 8, decode: 0 };
    let m = simulate(&SystemSpec::grace(0.15), &cfg);
    assert!(m.e2e_time > 0.0);
    cfg.workload = Workload { batch: 16, prefill: 1, decode: 12 };
    let m2 = simulate(&SystemSpec::grace(0.15), &cfg);
    assert!(m2.e2e_time > m.e2e_time * 0.5);
}
