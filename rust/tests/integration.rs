//! Cross-module integration tests: the full offline→online pipeline over
//! the simulator, exercised end to end with paper-shaped assertions.

use grace_moe::baselines::{GroupingStrategy, SystemSpec};
use grace_moe::cluster::Topology;
use grace_moe::comm::CommModel;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::coordinator::Coordinator;
use grace_moe::engine::sim::{build_placement, simulate,
                             simulate_with_placement, SimConfig};
use grace_moe::grouping::is_partition;
use grace_moe::placement::{Placement, ReplicationMode};
use grace_moe::profile::ModelProfile;
use grace_moe::routing::RoutingPolicy;
use grace_moe::stats::Rng;
use grace_moe::testutil::{check, prop_assert};
use grace_moe::trace::{Profile, TraceGen};

fn small(model: ModelSpec, topo: Topology) -> SimConfig {
    let model = ModelSpec { moe_layers: 3, ..model };
    let mut cfg = SimConfig::new(
        model,
        topo,
        Workload { batch: 64, prefill: 16, decode: 4 },
    );
    cfg.profile_tokens = 512;
    cfg.max_chunk = 1024;
    cfg
}

#[test]
fn full_pipeline_all_models_all_clusters() {
    for model in ModelSpec::all() {
        for topo in [Topology::two_by_two(), Topology::two_by_four()] {
            let cfg = small(model.clone(), topo);
            let m = simulate(&SystemSpec::grace(0.15), &cfg);
            assert!(m.e2e_time > 0.0, "{}: zero e2e", model.name);
            assert!(m.moe_layer_time > 0.0);
            assert!(m.a2a_time > 0.0);
            assert_eq!(m.layer_load_std.len(), 3 * 2);
        }
    }
}

#[test]
fn grace_placement_respects_memory_budget() {
    let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    let p = build_placement(&SystemSpec::grace(0.15), &cfg);
    // full-scale OLMoE expert ≈ 12.6 MB bf16; must fit easily in 80 GB
    p.check_memory(&cfg.topo, cfg.model.expert_bytes())
        .expect("placement must fit HBM");
    // replication is sparse (paper: "only a small subset of heavily
    // skewed experts per layer")
    assert!(p.replication_overhead() < 0.5,
            "overhead {}", p.replication_overhead());
}

#[test]
fn every_fig4_system_runs_and_orders_sanely() {
    let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    let systems = SystemSpec::fig4_systems(0.15);
    let runs: Vec<_> =
        systems.iter().map(|s| simulate(s, &cfg)).collect();
    // GRACE (last) must beat the vanilla baseline (first) clearly.
    let vanilla = &runs[0];
    let grace = runs.last().unwrap();
    assert!(
        grace.e2e_time < vanilla.e2e_time,
        "grace {} !< vanilla {}",
        grace.e2e_time,
        vanilla.e2e_time
    );
    // every system processes the same token count
    for m in &runs {
        assert_eq!(m.tokens, cfg.workload.total_tokens());
    }
}

#[test]
fn table1_ladder_reproduces_paper_directions() {
    // The qualitative Table-1 signature, averaged over the three models.
    let mut avg: Vec<grace_moe::metrics::RunMetrics> =
        (0..6).map(|_| Default::default()).collect();
    for model in ModelSpec::all() {
        let mut cfg = small(model, Topology::two_by_two());
        cfg.serve_profile = Profile::Math;
        cfg.placement_profile = Profile::Math;
        let ladder = SystemSpec::table1_ladder(0.15);
        for (acc, sys) in avg.iter_mut().zip(&ladder) {
            acc.accumulate(&simulate(sys, &cfg));
        }
    }
    let (occult, occult_hsc, hg_hsc, _fr, dr_wrr, dr_tar) =
        (&avg[0], &avg[1], &avg[2], &avg[3], &avg[4], &avg[5]);
    // RQ1: HSC cuts A2A time and cross traffic; shifts to intra.
    assert!(occult_hsc.a2a_time < occult.a2a_time);
    assert!(occult_hsc.cross_bytes < occult.cross_bytes);
    assert!(occult_hsc.intra_bytes > occult.intra_bytes);
    // HG cuts cross traffic further…
    assert!(hg_hsc.cross_bytes < occult_hsc.cross_bytes);
    // RQ2: …but worsens load balance; DR+WRR recovers it.
    assert!(hg_hsc.mean_load_std() > occult_hsc.mean_load_std());
    assert!(dr_wrr.mean_load_std() < hg_hsc.mean_load_std());
    assert!(dr_wrr.idle_time < hg_hsc.idle_time);
    // RQ3: TAR trims the traffic DR+WRR added.
    assert!(dr_tar.cross_bytes <= dr_wrr.cross_bytes);
    // Full ladder beats Occult end-to-end.
    assert!(dr_tar.e2e_time < occult.e2e_time);
}

#[test]
fn cross_dataset_transfer_stays_competitive() {
    // Fig. 6 shape at small scale: transferred placements lose little vs
    // in-domain and stay ahead of Occult.
    let sys = SystemSpec::grace(0.15);
    let mk = |serve, place| {
        let mut cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
        cfg.serve_profile = serve;
        cfg.placement_profile = place;
        cfg
    };
    for &target in &Profile::ALL {
        let indomain =
            simulate(&sys, &mk(target, target)).e2e_time;
        let occult =
            simulate(&SystemSpec::occult(), &mk(target, target)).e2e_time;
        for &src in &Profile::ALL {
            if src == target {
                continue;
            }
            let cfg = mk(target, src);
            let placement = build_placement(&sys, &cfg);
            let transferred =
                simulate_with_placement(&sys, &cfg, &placement).e2e_time;
            assert!(
                transferred < indomain * 1.25,
                "{src:?}→{target:?}: {transferred} vs in-domain \
                 {indomain}"
            );
            assert!(
                transferred < occult,
                "{src:?}→{target:?}: transferred {transferred} !< \
                 occult {occult}"
            );
        }
    }
}

#[test]
fn property_pipeline_is_total_over_random_configs() {
    check(15, |rng| {
        let models = ModelSpec::all();
        let model = models[rng.index(3)].clone();
        let topo = Topology::paper_testbed(1 + rng.index(3),
                                           1 + rng.index(4));
        if topo.num_gpus() < 2 {
            return Ok(());
        }
        let mut cfg = small(model, topo);
        cfg.seed = rng.next_u64();
        cfg.workload = Workload {
            batch: 8 + rng.index(64),
            prefill: 1 + rng.index(32),
            decode: rng.index(8),
        };
        let sys = match rng.index(4) {
            0 => SystemSpec::grace(0.05 + rng.f64() * 0.5),
            1 => SystemSpec::occult(),
            2 => SystemSpec::c2r(),
            _ => SystemSpec {
                comm: CommModel::StagedHierarchical,
                ..SystemSpec::occult()
            },
        };
        let m = simulate(&sys, &cfg);
        prop_assert(m.e2e_time.is_finite() && m.e2e_time > 0.0,
                    "bad e2e")?;
        prop_assert(m.cross_bytes >= 0.0 && m.intra_bytes >= 0.0,
                    "negative traffic")?;
        prop_assert(m.idle_time >= -1e-9, "negative idle")
    });
}

#[test]
fn property_groupings_stay_partitions_through_placement() {
    check(10, |rng| {
        let cfg = small(ModelSpec::olmoe(), Topology::two_by_four());
        let strategies = [
            GroupingStrategy::Sequential,
            GroupingStrategy::Uniform,
            GroupingStrategy::Hierarchical { r: rng.f64() },
            GroupingStrategy::FullyNonUniform,
        ];
        let sys = SystemSpec {
            grouping: strategies[rng.index(4)],
            replication: [ReplicationMode::None, ReplicationMode::Fixed,
                          ReplicationMode::Dynamic][rng.index(3)],
            routing: [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                      RoutingPolicy::Tar][rng.index(3)],
            ..SystemSpec::occult()
        };
        let p = build_placement(&sys, &cfg);
        for lp in &p.layers {
            prop_assert(is_partition(&lp.groups, p.experts),
                        "groups not a partition")?;
            for (e, inst) in lp.instances.iter().enumerate() {
                prop_assert(inst[0] == lp.primary[e], "primary first")?;
            }
        }
        Ok(())
    });
}

#[test]
fn coordinator_pipeline_matches_hand_wired_path() {
    // The engines now assemble the pipeline exclusively through the L3
    // Coordinator; this pins the refactor down: the coordinator-built
    // placement and run metrics must be *identical* to what the
    // previously hand-wired offline phase (trace generation → profiling →
    // Placement::build with the per-system grouping closure) produced.
    for sys in [SystemSpec::grace(0.15), SystemSpec::occult()] {
        let cfg = small(ModelSpec::olmoe(), Topology::two_by_two());

        // Hand-wired path (verbatim pre-coordinator wiring, including the
        // grouping-RNG seed derivation).
        let profiling = TraceGen {
            experts: cfg.model.experts,
            top_k: cfg.model.top_k,
            layers: cfg.model.moe_layers,
            profile: cfg.placement_profile,
            seed: cfg.seed,
        }
        .generate(cfg.profile_tokens);
        let profile = ModelProfile::from_trace(&profiling);
        let mut rng = Rng::new(cfg.seed ^ 0x9A0C);
        let hand = Placement::build(&profile, sys.replication, |lp| {
            sys.grouping.build(lp, &cfg.topo, &mut rng)
        });

        // Coordinator path (what the sim engine does today).
        let coord = Coordinator::for_system(&sys, &cfg.topo, cfg.seed);
        let coordinated = coord.offline_synthetic(
            &cfg.model,
            cfg.placement_profile,
            cfg.profile_tokens,
        );

        assert_eq!(hand.layers.len(), coordinated.layers.len());
        for (h, c) in hand.layers.iter().zip(&coordinated.layers) {
            assert_eq!(h.groups, c.groups, "{}: groups differ", sys.name);
            assert_eq!(h.primary, c.primary);
            assert_eq!(h.instances, c.instances);
            assert_eq!(h.replication, c.replication);
            assert_eq!(h.polling, c.polling);
        }

        // And the online phase over both placements must be
        // metric-identical, bit for bit.
        let a = simulate_with_placement(&sys, &cfg, &hand);
        let b = simulate_with_placement(&sys, &cfg, &coordinated);
        assert_eq!(a.e2e_time, b.e2e_time, "{}", sys.name);
        assert_eq!(a.moe_layer_time, b.moe_layer_time);
        assert_eq!(a.a2a_time, b.a2a_time);
        assert_eq!(a.cross_bytes, b.cross_bytes);
        assert_eq!(a.intra_bytes, b.intra_bytes);
        assert_eq!(a.idle_time, b.idle_time);
        assert_eq!(a.layer_load_std, b.layer_load_std);
        assert_eq!(a.launches, b.launches);
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn decode_only_and_prefill_only_workloads() {
    let mut cfg = small(ModelSpec::olmoe(), Topology::two_by_two());
    cfg.workload = Workload { batch: 16, prefill: 8, decode: 0 };
    let m = simulate(&SystemSpec::grace(0.15), &cfg);
    assert!(m.e2e_time > 0.0);
    cfg.workload = Workload { batch: 16, prefill: 1, decode: 12 };
    let m2 = simulate(&SystemSpec::grace(0.15), &cfg);
    assert!(m2.e2e_time > m.e2e_time * 0.5);
}
