//! Scaling sweep (beyond-paper extension): how the GRACE-MoE advantage
//! evolves with cluster size and with the intra/cross bandwidth gap.
//!
//! The paper evaluates 2×2 and 2×4; this example extends the sweep to
//! more nodes and to degraded cross-node links, showing that the
//! advantage grows exactly where the paper's motivation says it should —
//! when cross-node bandwidth is the bottleneck.
//!
//! Run: `cargo run --release --example scaling_sweep`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::{simulate, SimConfig};

fn main() {
    let occult = SystemSpec::occult();
    let grace = SystemSpec::grace(0.15);

    println!("=== cluster-size sweep (OLMoE, workload i) ===");
    let mut t = Table::new(&[
        "CLUSTER",
        "OCCULT E2E (ms)",
        "GRACE E2E (ms)",
        "SPEEDUP",
        "CROSS GB (occ→grace)",
    ]);
    for (nodes, gpus) in [(1, 4), (2, 2), (2, 4), (4, 2), (4, 4)] {
        let cfg = SimConfig::new(
            ModelSpec::olmoe(),
            Topology::paper_testbed(nodes, gpus),
            Workload::heavy_i(),
        );
        let o = simulate(&occult, &cfg);
        let g = simulate(&grace, &cfg);
        t.row(vec![
            format!("{nodes}x{gpus}"),
            format!("{:.1}", o.e2e_time * 1e3),
            format!("{:.1}", g.e2e_time * 1e3),
            format!("{:.2}x", o.e2e_time / g.e2e_time),
            format!("{:.2} → {:.2}", o.cross_bytes / 1e9,
                    g.cross_bytes / 1e9),
        ]);
    }
    println!("{}", t.render());

    println!("=== cross-node bandwidth sweep (2x4, workload i) ===");
    let mut t = Table::new(&["CROSS-NODE BW", "OCCULT (ms)", "GRACE (ms)",
                             "SPEEDUP"]);
    for gbps in [100.0, 50.0, 25.0, 10.0] {
        let mut topo = Topology::two_by_four();
        topo.inter_bw = gbps * 1e9 / 8.0;
        let cfg = SimConfig::new(ModelSpec::olmoe(), topo,
                                 Workload::heavy_i());
        let o = simulate(&occult, &cfg);
        let g = simulate(&grace, &cfg);
        t.row(vec![
            format!("{gbps:.0} Gbps"),
            format!("{:.1}", o.e2e_time * 1e3),
            format!("{:.1}", g.e2e_time * 1e3),
            format!("{:.2}x", o.e2e_time / g.e2e_time),
        ]);
    }
    println!("{}", t.render());
    println!("(expected: the speedup grows as cross-node bandwidth \
              shrinks — communication is the bottleneck GRACE removes)");
}
