//! Offline-phase walkthrough: profiling → affinity → knee-point r
//! selection → hierarchical grouping → dynamic replication, with every
//! intermediate artifact printed.
//!
//! This is the "Fig. 2(a)+(b)" example: it shows exactly what the
//! offline phase computes before any request is served.
//!
//! Run: `cargo run --release --example offline_placement`

use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::grouping::{hierarchical, select_r, tradeoff_curve};
use grace_moe::placement::{LayerPlacement, ReplicationMode};
use grace_moe::profile::{size_deviation, ModelProfile};
use grace_moe::stats::Rng;
use grace_moe::trace::{Profile, TraceGen};

fn main() {
    let topo = Topology::two_by_two();
    let experts = 64;

    // --- profiling: record expert selections, build affinity + loads ---
    let trace = TraceGen {
        experts,
        top_k: 8,
        layers: 4,
        profile: Profile::Math,
        seed: 2024,
    }
    .generate(2048);
    let profile = ModelProfile::from_trace(&trace);
    let lp = &profile.layers[0];
    println!("profiled 2048 tokens; layer-0 expert load: min {:.0} max \
              {:.0}",
             lp.load.iter().cloned().fold(f64::INFINITY, f64::min),
             lp.load.iter().cloned().fold(0.0, f64::max));

    // top co-activated pairs — the affinity signal grouping exploits
    let mut pairs = Vec::new();
    for i in 0..experts {
        for j in (i + 1)..experts {
            pairs.push((lp.affinity[(i, j)], i, j));
        }
    }
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!("hottest co-activation pairs: {:?}",
             pairs[..5]
                 .iter()
                 .map(|&(a, i, j)| format!("({i},{j})×{a:.0}"))
                 .collect::<Vec<_>>());

    // --- knee-point selection of the non-uniformity ratio r -------------
    let candidates = [0.0, 0.05, 0.1, 0.15, 0.25, 0.4, 0.6, 1.0];
    let mut rng = Rng::new(1);
    let curve = tradeoff_curve(lp, 4, &candidates, &mut rng);
    let mut t = Table::new(&["r", "U(r)", "S(r)"]);
    for (r, u, s) in &curve {
        t.row(vec![format!("{r:.2}"), format!("{u:.4}"),
                   format!("{s:.3}")]);
    }
    println!("\n{}", t.render());
    let r_star = select_r(lp, 4, &candidates, &mut rng);
    println!("knee point: r* = {r_star}");

    // --- hierarchical grouping + dynamic replication ---------------------
    println!("\nper-layer placement (hierarchical grouping, r = {r_star}):");
    for (l, lp) in profile.layers.iter().enumerate() {
        let groups = hierarchical(lp, &topo, r_star, &mut rng);
        let placement = LayerPlacement::build(lp, groups,
                                              ReplicationMode::Dynamic);
        let sizes: Vec<usize> =
            placement.groups.iter().map(Vec::len).collect();
        println!(
            "  layer {l}: sizes {:?} (S = {:.2}, U = {:.3}); loads {:?}; \
             ρ-driven replication: {} hot experts → gpus {:?}; polling \
             weights {:?}",
            sizes,
            size_deviation(&placement.groups, experts),
            lp.affinity_utilization(&placement.groups),
            placement
                .pre_loads
                .iter()
                .map(|w| *w as i64)
                .collect::<Vec<_>>(),
            placement.replication.hot_experts.len(),
            placement.replication.replica_gpus,
            placement
                .polling
                .iter()
                .map(|w| format!("{w:.2}"))
                .collect::<Vec<_>>()
        );
    }
}
