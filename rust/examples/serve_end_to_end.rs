//! End-to-end serving driver — the full three-layer stack on a real
//! (tiny) model:
//!
//! 1. loads the AOT-compiled OLMoE-style variant (JAX/Pallas → HLO text →
//!    PJRT CPU),
//! 2. profiles the *real* gate to build the affinity/load statistics,
//! 3. runs the offline phase (hierarchical grouping + dynamic
//!    replication),
//! 4. serves batched requests through the router/batcher with
//!    topology-aware routing — every expert FFN is a real PJRT execution
//!    on the rank routing chose (the dense per-expert CPU fast path;
//!    see EXPERIMENTS.md §Perf),
//! 5. validates losslessness against the single-device oracle using the
//!    L1 Pallas grouped kernel, and
//! 6. reports per-request latency and token throughput.
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_end_to_end`

use grace_moe::baselines::GroupingStrategy;
use grace_moe::cluster::Topology;
use grace_moe::coordinator::{Coordinator, OnlineCoordinator};
use grace_moe::engine::real::{profile_real, DistributedMoE, FfnMode,
                              RealModel};
use grace_moe::placement::ReplicationMode;
use grace_moe::routing::RoutingPolicy;
use grace_moe::server::{MoEServer, Request, ServerConfig};
use grace_moe::stats::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| {
        "artifacts".to_string()
    });
    let topo = Topology::two_by_two();
    let seed = 42;

    println!("== 1. load AOT model ==");
    let t0 = Instant::now();
    let model = Arc::new(RealModel::load(&dir, "olmoe_tiny")?);
    println!(
        "loaded olmoe_tiny: E={} K={} L={} H={} (PJRT platform: {}) in \
         {:.1}s",
        model.cfg.experts,
        model.cfg.top_k,
        model.cfg.layers,
        model.cfg.hidden,
        model.eng.platform(),
        t0.elapsed().as_secs_f64()
    );

    println!("\n== 2–3. offline phase: real-gate profiling + placement ==");
    let t0 = Instant::now();
    let trace = profile_real(&model, 2, seed)?;
    // The L3 coordinator owns the pipeline: offline placement here, and
    // the per-layer routers for every check/serve below.
    let coord = Coordinator::new(
        GroupingStrategy::Hierarchical { r: 0.15 },
        ReplicationMode::Dynamic,
        RoutingPolicy::Tar,
        topo.clone(),
        seed,
    );
    let placement = coord.place(&trace);
    println!(
        "profiled {} tokens × {} layers in {:.1}s",
        trace.num_tokens(),
        trace.num_layers(),
        t0.elapsed().as_secs_f64()
    );
    for (l, lp) in placement.layers.iter().enumerate() {
        println!(
            "  layer {l}: group sizes {:?}, {} hot experts replicated to \
             {:?}",
            lp.groups.iter().map(Vec::len).collect::<Vec<_>>(),
            lp.replication.hot_experts.len(),
            lp.replication.replica_gpus
        );
    }

    println!("\n== 5. losslessness check (distributed vs oracle) ==");
    let placement = Arc::new(placement);
    let mut rng = Rng::new(9);
    let c = model.cfg.clone();
    let x: Vec<f32> = (0..c.tile_t * c.hidden)
        .map(|_| rng.gaussian() as f32 * 0.5)
        .collect();
    for policy in [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                   RoutingPolicy::Tar, RoutingPolicy::LoadAware] {
        let policy_coord = OnlineCoordinator::new(topo.clone(), policy);
        let mut dist = DistributedMoE::new(model.clone(),
                                           placement.clone(),
                                           &policy_coord,
                                           FfnMode::GroupedPallas);
        let want = model.moe_layer_oracle(&x, 0)?;
        let run = dist.moe_layer(&x, 0, &(|t| t % topo.num_gpus()),
                                 &mut Rng::new(5))?;
        let max_err = run
            .y
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("  {:<8} max |distributed − oracle| = {max_err:.2e}  \
                  copies/gpu = {:?}",
                 policy.name(), run.plan.copies_per_gpu());
        anyhow::ensure!(max_err < 5e-4, "losslessness violated");
    }
    println!("  lossless ✓ (same numerics under every routing policy)");

    println!("\n== 4+6. serve batched requests (TAR routing) ==");
    let mut server = MoEServer::with_coordinator(
        model.clone(),
        placement.clone(),
        coord.clone(),
        ServerConfig {
            max_batch: 8,
            queue_cap: 64,
            seed,
            ffn_mode: FfnMode::PerExpert,
            ..ServerConfig::default()
        },
    );
    let mut rng = Rng::new(seed);
    let requests: Vec<Request> = (0..8)
        .map(|i| Request {
            id: i,
            prompt: (0..24).map(|_| rng.index(c.vocab) as i32).collect(),
            max_new_tokens: 8,
            priority: 0,
        })
        .collect();
    let t0 = Instant::now();
    let (responses, metrics) = server.serve(requests)?;
    println!("served {} requests in {:.2}s", responses.len(),
             t0.elapsed().as_secs_f64());
    for r in &responses {
        println!("  request {}: {:?} ({:.0} ms)", r.id, r.tokens,
                 r.latency * 1e3);
    }
    let s = metrics.latency_summary().expect("latencies");
    println!(
        "latency mean {:.0} ms  p50 {:.0} ms  p99 {:.0} ms  | \
         throughput {:.1} tok/s  | {} PJRT executions",
        s.mean() * 1e3,
        s.p50() * 1e3,
        s.p99() * 1e3,
        metrics.throughput_tps(),
        model.eng.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    );
    if let Some(t) = metrics.ttft_summary() {
        println!(
            "ttft mean {:.0} ms  p95 {:.0} ms  | {} steps, {} dispatch \
             rounds ({:.2} rounds/token)",
            t.mean() * 1e3,
            t.p95() * 1e3,
            metrics.steps,
            metrics.dispatch_rounds,
            metrics.rounds_per_token()
        );
    }

    // Determinism spot-check: greedy decode twice must agree.
    let mut server2 = MoEServer::with_coordinator(
        model.clone(),
        placement,
        coord,
        ServerConfig {
            max_batch: 8,
            queue_cap: 64,
            seed,
            ffn_mode: FfnMode::PerExpert,
            ..ServerConfig::default()
        },
    );
    let mut rng = Rng::new(seed);
    let again: Vec<Request> = (0..8)
        .map(|i| Request {
            id: i,
            prompt: (0..24).map(|_| rng.index(c.vocab) as i32).collect(),
            max_new_tokens: 8,
            priority: 0,
        })
        .collect();
    let (responses2, _) = server2.serve(again)?;
    for (a, b) in responses.iter().zip(&responses2) {
        anyhow::ensure!(a.tokens == b.tokens,
                        "non-deterministic decode for request {}", a.id);
    }
    println!("greedy decode deterministic across runs ✓");
    Ok(())
}
