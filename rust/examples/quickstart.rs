//! Quickstart: simulate GRACE-MoE vs Occult on the paper's testbed and
//! print the comparison — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::{simulate, SimConfig};
use grace_moe::report;

fn main() {
    // 1. Describe the deployment: OLMoE on 2 nodes × 2 GPUs, the paper's
    //    workload (i) — 256 sequences, 128 prefill + 16 decode tokens.
    let cfg = SimConfig::new(
        ModelSpec::olmoe(),
        Topology::two_by_two(),
        Workload::heavy_i(),
    );

    // 2. Pick the systems to compare. GRACE-MoE = hierarchical
    //    non-uniform grouping + dynamic replication + topology-aware
    //    routing on hierarchical sparse communication.
    let occult = SystemSpec::occult();
    let grace = SystemSpec::grace(0.15);

    // 3. Run: offline phase (profile → group → replicate) + online phase
    //    (route → communicate → compute), then report.
    let runs = vec![simulate(&occult, &cfg), simulate(&grace, &cfg)];
    println!("{}",
             report::e2e_table(&["occult", "grace-moe"], &runs).render());
    println!(
        "GRACE-MoE speedup over Occult: {:.2}x (paper §6.3: 1.45x on \
         OLMoE)",
        runs[0].e2e_time / runs[1].e2e_time
    );
    println!(
        "cross-node traffic: {:.2} GB → {:.2} GB",
        runs[0].cross_bytes / 1e9,
        runs[1].cross_bytes / 1e9
    );
}
