//! Drifting-workload bench: static GRACE vs the epoch re-planned
//! `grace-dyn` on a serving trace whose hot-expert set rotates mid-run.
//!
//! The offline phase profiles the *pre-drift* distribution, so the
//! static system keeps balancing yesterday's hot experts for the whole
//! second act; the re-planned system detects the skew drift from
//! measured loads, migrates replicas (migration bytes are priced into
//! its latency), and re-flattens the load. Reported per system:
//! end-to-end latency, A2A time, max per-GPU load share over the
//! post-drift rounds, migration traffic, and applied re-plans — plus
//! wall-clock of the replay itself.
//!
//! Run: `cargo bench --bench replan`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::{bench, Table};
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::sim::{build_placement, drifting_rounds,
                             simulate_rounds, SimConfig};
use grace_moe::replan::ReplanConfig;
use grace_moe::trace::Profile;

const ROUNDS: usize = 18;
const DRIFT_AT: usize = 6;
const TOKENS: usize = 2048;

fn main() {
    let model = ModelSpec { moe_layers: 4, ..ModelSpec::olmoe() };
    let mut cfg = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload::heavy_i(),
    );
    cfg.serve_profile = Profile::Math; // strongest skew
    cfg.placement_profile = Profile::Math;
    cfg.profile_tokens = 1024;
    let rc = ReplanConfig {
        epoch_rounds: 2,
        min_drift: 0.05,
        payback: 1.0,
        ..ReplanConfig::default()
    };

    let sys = SystemSpec::grace(0.15);
    let dyn_sys = SystemSpec::grace_dyn(0.15);
    let placement = build_placement(&sys, &cfg);
    let shift = cfg.model.experts / 2;
    let rounds = drifting_rounds(&cfg, ROUNDS, DRIFT_AT, shift, TOKENS);
    println!(
        "{ROUNDS} rounds x {TOKENS} tokens, hot set rotates by {shift} \
         at round {DRIFT_AT}; epoch {} rounds, threshold {}",
        rc.epoch_rounds, rc.min_drift
    );

    let mut table = Table::new(&[
        "SYSTEM",
        "E2E (ms)",
        "A2A (ms)",
        "MAX SHARE (post-drift)",
        "MIGRATION (MB)",
        "REPLANS",
    ]);
    for (name, replan) in
        [("grace (static)", None), ("grace-dyn", Some(rc))]
    {
        let (m, rep) =
            simulate_rounds(&sys_for(name, &sys, &dyn_sys), &cfg,
                            &placement, &rounds, replan);
        table.row(vec![
            name.to_string(),
            format!("{:.2}", m.e2e_time * 1e3),
            format!("{:.2}", m.a2a_time * 1e3),
            format!("{:.3}", rep.max_load_share(DRIFT_AT)),
            format!("{:.1}", m.migration_bytes / 1e6),
            format!("{}", m.replans),
        ]);

        let r = bench(&format!("replay {ROUNDS} rounds ({name})"), 1, 5,
                      || {
            simulate_rounds(&sys_for(name, &sys, &dyn_sys), &cfg,
                            &placement, &rounds, replan)
        });
        println!("{}", r.report_line());
    }
    println!("{}", table.render());
}

fn sys_for(name: &str, stat: &SystemSpec, dynamic: &SystemSpec)
           -> SystemSpec {
    if name.contains("dyn") {
        dynamic.clone()
    } else {
        stat.clone()
    }
}
