//! Figure 7 (Appendix A.5) — end-to-end latency and MoE layer time under
//! *lighter* workloads on the 2 nodes × 4 GPUs/node cluster:
//! (i) bs=64, prefill=128, decode=16 and (ii) bs=128, prefill=64,
//! decode=32.
//!
//! Expected shape: same ordering as Fig. 4 — GRACE-MoE stays ahead of all
//! baselines even when communication pressure is reduced.
//!
//! Run: `cargo bench --bench fig7_light_workloads`

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::sim::{build_placement, simulate_with_placement,
                             SimConfig};
use grace_moe::placement::Placement;
use grace_moe::report;
use std::collections::HashMap;

fn main() {
    let systems = SystemSpec::fig4_systems(0.15);
    let workloads = [Workload::light_i(), Workload::light_ii()];
    let topo = Topology::two_by_four();

    for model in ModelSpec::all() {
        let mut placements: HashMap<String, Placement> = HashMap::new();
        for workload in &workloads {
            let cfg =
                SimConfig::new(model.clone(), topo.clone(), *workload);
            let names: Vec<&str> =
                systems.iter().map(|s| s.name).collect();
            let runs: Vec<_> = systems
                .iter()
                .map(|s| {
                    let key =
                        format!("{:?}{:?}", s.grouping, s.replication);
                    let p = placements
                        .entry(key)
                        .or_insert_with(|| build_placement(s, &cfg));
                    simulate_with_placement(s, &cfg, p)
                })
                .collect();
            println!(
                "\n=== Fig7: model={} cluster=2x4 workload={} ===",
                model.name,
                workload.label()
            );
            println!("{}", report::e2e_table(&names, &runs).render());
            let grace = runs.last().unwrap().e2e_time;
            let best_baseline = runs[..runs.len() - 1]
                .iter()
                .map(|m| m.e2e_time)
                .fold(f64::INFINITY, f64::min);
            println!(
                "GRACE vs best baseline: {:.2}x",
                best_baseline / grace
            );
        }
    }
}
