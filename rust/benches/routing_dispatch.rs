//! Routing hot-path microbenchmark: scalar per-assignment selection vs
//! batched plan dispatch, per policy.
//!
//! The scalar rows measure the old engine shape (one `select` call per
//! expert assignment, plan assembly by hand in the caller); the batched
//! rows measure one `Dispatcher::dispatch` round producing the full
//! `DispatchPlan` (transfer lists + per-token view + byte accounting).
//! Wired into the CI bench-smoke job like every other target.
//!
//! Run: `cargo bench --bench routing_dispatch`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::bench;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::coordinator::Coordinator;
use grace_moe::engine::sim::{build_placement, SimConfig};
use grace_moe::routing::{Assignment, RouteCtx, RoutingPolicy};
use grace_moe::stats::Rng;

const TOKENS: usize = 4096;
const TOP_K: usize = 8;

fn main() {
    let topo = Topology::two_by_two();
    let model = ModelSpec::olmoe();
    let cfg = SimConfig::new(model.clone(), topo.clone(),
                             Workload::heavy_i());
    let sys = SystemSpec::grace(0.15);
    let placement = build_placement(&sys, &cfg);
    let lp = &placement.layers[0];

    let batch: Vec<Assignment> = (0..TOKENS)
        .flat_map(|t| {
            (0..TOP_K).map(move |k| Assignment {
                token: t,
                expert: (t * 7 + k * 13) % 64,
                src: t % 4,
            })
        })
        .collect();

    for policy in [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                   RoutingPolicy::Tar, RoutingPolicy::LoadAware] {
        // Scalar: one select per assignment, no plan assembly.
        let mut pol = policy.build();
        let ctx = RouteCtx { placement: lp, topo: &topo, layer: 0 };
        let mut rng = Rng::new(1);
        let r = bench(
            &format!("scalar select {TOKENS}x{TOP_K} ({})",
                     policy.name()),
            3,
            30,
            || {
                let mut acc = 0usize;
                for a in &batch {
                    acc += pol.select(&ctx, a.src, a.expert, &mut rng);
                }
                pol.end_round(&ctx);
                acc
            },
        );
        println!("{}", r.report_line());

        // Batched: one dispatch round, full DispatchPlan emitted.
        let coord = Coordinator::new(
            sys.grouping,
            sys.replication,
            policy,
            topo.clone(),
            cfg.seed,
        );
        let mut dispatcher = coord.dispatcher(model.token_bytes());
        let mut rng = Rng::new(1);
        let r = bench(
            &format!("batched dispatch {TOKENS}x{TOP_K} ({})",
                     policy.name()),
            3,
            30,
            || dispatcher.dispatch(lp, 0, &batch, &mut rng),
        );
        println!("{}", r.report_line());
    }
}
