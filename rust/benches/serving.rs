//! Serving-core bench: static-drain vs continuous batching on identical
//! workloads, over the real scheduler on a virtual clock.
//!
//! Both arms run [`grace_moe::server::sched::simulate_serve`] — the same
//! state machine the execute-mode server drives — with a deterministic
//! token engine and an analytic step-cost model (per-dispatch-round
//! overhead + per-token compute, A100-flavoured constants). The arms
//! differ in exactly two ways, the two PR-5 claims:
//!
//! * **discipline** — `StaticDrain` admits only at the drain barrier
//!   (the seed server); `Continuous` admits and retires at every step;
//! * **forward shape** — the static arm charges the seed server's
//!   per-sequence dispatch (`Σ ⌈len/tile_t⌉` rounds per layer), the
//!   continuous arm the batched shared-tile dispatch
//!   (`⌈Σ len/tile_t⌉` rounds per layer).
//!
//! Expected shape: continuous batching issues strictly fewer dispatch
//! rounds per generated token (denser plans), and under open-loop
//! Poisson arrivals its TTFT/queue-wait tails collapse relative to the
//! drain barrier, at equal or better token throughput. The wall-clock
//! `report_line` at the end times the scheduler machinery itself.
//!
//! Run: `cargo bench --bench serving`
//! JSON archive: `cargo bench --bench serving -- --json`, or
//! `BENCH_JSON=<dir>` (the `make bench-record` path) — writes
//! `BENCH_serving.json` with both arms of every workload plus the
//! self-check verdict.

use grace_moe::bench::{bench, JsonRecorder, Table};
use grace_moe::config::{ArrivalProcess, ServeLoad};
use grace_moe::configio::Value;
use grace_moe::server::sched::{simulate_serve, SchedConfig, SchedMode};
use grace_moe::server::Request;
use grace_moe::stats::Rng;
use grace_moe::testutil::fake_decode_token as fake_next;

const CTX: usize = 64;
const LAYERS: usize = 4;
const TILE_T: usize = 16;
/// Per-dispatch-round launch overhead, seconds (collective latency
/// floor).
const ROUND_S: f64 = 200e-6;
/// Per-token expert+dense compute, seconds.
const TOKEN_S: f64 = 40e-6;

fn requests(load: &ServeLoad) -> Vec<Request> {
    (0..load.requests)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..load.prompt)
                .map(|p| ((i * 131 + p * 17) % 512) as i32)
                .collect(),
            max_new_tokens: load.new_tokens,
            priority: 0,
        })
        .collect()
}

/// One serving run: returns the metrics of the configured arm.
fn run_arm(load: &ServeLoad, mode: SchedMode, seed: u64)
           -> grace_moe::metrics::ServeMetrics {
    let mut rng = Rng::new(seed);
    let times = load.arrival_times(&mut rng);
    let arrivals: Vec<(Request, f64)> =
        requests(load).into_iter().zip(times).collect();
    let cfg = SchedConfig {
        mode,
        max_batch: 8,
        max_batch_tokens: 4 * CTX,
        ctx: CTX,
        // Both arms price full prefixes: this bench isolates the PR-5
        // discipline/forward-shape comparison (KV-cached pricing gets
        // its own bench, `benches/kv_cache.rs`).
        kv_cache: false,
        ..SchedConfig::default()
    };
    let (_, metrics) = simulate_serve(
        cfg,
        arrivals,
        |seqs| {
            let tokens: usize =
                seqs.iter().map(|(_, ids, _)| ids.len()).sum();
            let rounds = match mode {
                // Seed server: one forward per sequence per step.
                SchedMode::StaticDrain => seqs
                    .iter()
                    .map(|(_, ids, _)| {
                        LAYERS * ids.len().div_ceil(TILE_T)
                    })
                    .sum(),
                // Batched decode: shared tiles across the live batch.
                SchedMode::Continuous => {
                    LAYERS * tokens.div_ceil(TILE_T)
                }
            };
            let next =
                seqs.iter().map(|(_, ids, _)| fake_next(ids)).collect();
            Ok((next, rounds))
        },
        |tokens, rounds| {
            rounds as f64 * ROUND_S + tokens as f64 * TOKEN_S
        },
    )
    .expect("serving run");
    metrics
}

fn main() {
    let loads = [
        ServeLoad {
            requests: 64,
            prompt: 12,
            new_tokens: 16,
            arrival: ArrivalProcess::Closed,
        },
        ServeLoad {
            requests: 64,
            prompt: 12,
            new_tokens: 16,
            arrival: ArrivalProcess::Poisson { rate: 24.0 },
        },
        ServeLoad {
            requests: 96,
            prompt: 24,
            new_tokens: 8,
            arrival: ArrivalProcess::Poisson { rate: 48.0 },
        },
    ];

    let mut rec = JsonRecorder::from_env("serving");
    let mut table = Table::new(&[
        "WORKLOAD",
        "SCHED",
        "ROUNDS",
        "ROUNDS/TOK",
        "TTFT p50 (ms)",
        "TTFT p95 (ms)",
        "TTFT p99 (ms)",
        "TPOT p50 (ms)",
        "QWAIT p95 (ms)",
        "TOK/S",
    ]);

    for load in &loads {
        let mut per_mode = Vec::new();
        for (name, mode) in [("static-drain", SchedMode::StaticDrain),
                             ("continuous", SchedMode::Continuous)]
        {
            let m = run_arm(load, mode, 7);
            let ttft = m.ttft_summary().expect("ttft");
            let tpot = m.tpot_summary().expect("tpot");
            let qw = m.queue_wait_summary().expect("queue wait");
            table.row(vec![
                load.label(),
                name.to_string(),
                format!("{}", m.dispatch_rounds),
                format!("{:.2}", m.rounds_per_token()),
                format!("{:.1}", ttft.p50() * 1e3),
                format!("{:.1}", ttft.p95() * 1e3),
                format!("{:.1}", ttft.p99() * 1e3),
                format!("{:.2}", tpot.p50() * 1e3),
                format!("{:.1}", qw.p95() * 1e3),
                format!("{:.0}", m.throughput_tps()),
            ]);
            rec.record_value(
                &format!("{}/{}", load.label(), name),
                Value::object(vec![
                    ("dispatch_rounds", Value::from(m.dispatch_rounds)),
                    ("rounds_per_token",
                     Value::num(m.rounds_per_token())),
                    ("ttft_p99_ms", Value::num(ttft.p99() * 1e3)),
                    ("tpot_p50_ms", Value::num(tpot.p50() * 1e3)),
                    ("queue_wait_p95_ms", Value::num(qw.p95() * 1e3)),
                    ("throughput_tps", Value::num(m.throughput_tps())),
                ]),
            );
            per_mode.push(m);
        }
        // The PR-5 acceptance bar, self-checked on every bench run:
        // batched decode issues strictly fewer dispatch rounds per
        // generated token than the per-sequence static drain.
        assert!(
            per_mode[1].rounds_per_token()
                < per_mode[0].rounds_per_token(),
            "{}: continuous {} rounds/tok !< static {}",
            load.label(),
            per_mode[1].rounds_per_token(),
            per_mode[0].rounds_per_token()
        );
    }
    rec.record_value("self_check_rounds_per_token", Value::from(true));
    println!("{}", table.render());

    // Wall-clock of the scheduler machinery itself (admission, budget
    // walk, retirement) — the serving-core overhead per request.
    let load = loads[0];
    let r = bench("scheduler machinery (64 reqs, closed loop)", 2, 30,
                  || run_arm(&load, SchedMode::Continuous, 7));
    println!("{}", r.report_line());
    rec.record(&r);
    if let Some(path) = rec.finish().expect("write bench json") {
        println!("wrote {}", path.display());
    }
}
