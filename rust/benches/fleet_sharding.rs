//! Fleet-sharding bench: the PR-9 acceptance bars, self-checked on
//! every run.
//!
//! Two arms over the virtual-clock fleet replay
//! ([`grace_moe::engine::fleet::replay_fleet`]):
//!
//! * **scaling** — the same saturating Poisson trace through 1 vs 4
//!   jsq-routed replicas. Four replicas are 4× the hardware, so the
//!   bar is ≥ 2.5× the single-replica token throughput (the front-end,
//!   interleave, and residual imbalance are allowed to cost at most
//!   ~37%) *and* a strictly lower p95 TTFT — scale-out must shorten
//!   the admission queue, not just widen the pipe.
//! * **affinity** — class-conditioned traffic (`class_shift`) over
//!   class-specialised replicas (`replica_profiles`), jsq vs
//!   placement-affinity routing at equal completed token counts. The
//!   bar: affinity moves strictly fewer cross-node bytes, because it
//!   sends each class to the replica that locally replicates that
//!   class's hot experts instead of spraying classes over mismatched
//!   placements.
//!
//! Run: `cargo bench --bench fleet_sharding`
//! JSON archive: `cargo bench --bench fleet_sharding -- --json`, or
//! `BENCH_JSON=<dir>` (the `make bench-record` path) — writes
//! `BENCH_fleet_sharding.json` with both arms plus the self-check
//! verdicts.

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::{bench, JsonRecorder, Table};
use grace_moe::cluster::Topology;
use grace_moe::config::{ArrivalProcess, ModelSpec, ServeLoad, Workload};
use grace_moe::configio::Value;
use grace_moe::engine::fleet::{replay_fleet, FleetConfig, FleetReport};
use grace_moe::engine::SimConfig;
use grace_moe::server::shard::FleetRoutePolicy;

/// A saturating open-loop workload: arrivals far faster than any shard
/// drains, so the admission queue (not the arrival process) sets TTFT.
const REQUESTS: usize = 96;
const RATE: f64 = 1e4;

fn fleet_cfg(replicas: usize, route: FleetRoutePolicy) -> FleetConfig {
    let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
    let mut sim = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload { batch: 8, prefill: 16, decode: 4 },
    );
    sim.profile_tokens = 256;
    sim.max_chunk = 256;
    let load = ServeLoad {
        requests: REQUESTS,
        prompt: 16,
        new_tokens: 4,
        arrival: ArrivalProcess::Poisson { rate: RATE },
    };
    let mut cfg =
        FleetConfig::new(SystemSpec::grace(0.15), sim, load);
    // Tight admission limits so the single-replica arm actually queues.
    cfg.max_batch = 4;
    cfg.max_batch_tokens = 64;
    cfg.shard.replicas = replicas;
    cfg.shard.route = route;
    cfg
}

fn row(table: &mut Table, arm: &str, rep: &FleetReport) {
    let ttft = rep.serve.ttft_summary().expect("ttft");
    table.row(vec![
        arm.to_string(),
        format!("{}", rep.replicas),
        rep.serve.latencies.len().to_string(),
        format!("{:.0}", rep.serve.throughput_tps()),
        format!("{:.2}", ttft.p95() * 1e3),
        format!("{:.2}", rep.comm.cross_bytes / 1e6),
        format!("{:.2}", rep.fleet_imbalance()),
    ]);
}

fn report_json(rep: &FleetReport) -> Value {
    Value::object(vec![
        ("replicas", Value::from(rep.replicas)),
        ("requests", Value::from(rep.serve.latencies.len())),
        ("generated_tokens", Value::from(rep.serve.generated_tokens)),
        ("throughput_tps", Value::num(rep.serve.throughput_tps())),
        ("ttft_p95_ms",
         Value::num(rep.serve.ttft_summary()
             .map_or(0.0, |s| s.p95()) * 1e3)),
        ("cross_bytes", Value::num(rep.comm.cross_bytes)),
        ("fleet_imbalance", Value::num(rep.fleet_imbalance())),
    ])
}

fn main() {
    let mut rec = JsonRecorder::from_env("fleet_sharding");
    let mut table = Table::new(&[
        "ARM",
        "REPLICAS",
        "REQS",
        "TOK/S",
        "TTFT p95 (ms)",
        "CROSS MB",
        "IMBALANCE",
    ]);

    // ---- scaling: 1 vs 4 jsq replicas on the same saturating trace --
    let one = replay_fleet(&fleet_cfg(1, FleetRoutePolicy::Jsq))
        .expect("1-replica replay");
    let four = replay_fleet(&fleet_cfg(4, FleetRoutePolicy::Jsq))
        .expect("4-replica replay");
    row(&mut table, "scaling/jsq", &one);
    row(&mut table, "scaling/jsq", &four);
    rec.record_value("scaling/replicas1", report_json(&one));
    rec.record_value("scaling/replicas4", report_json(&four));

    assert_eq!(one.serve.latencies.len(), REQUESTS);
    assert_eq!(four.serve.latencies.len(), REQUESTS);
    for (r, m) in four.per_replica.iter().enumerate() {
        assert!(m.steps > 0, "replica {r} never stepped");
    }
    let speedup =
        four.serve.throughput_tps() / one.serve.throughput_tps();
    assert!(
        speedup >= 2.5,
        "4-replica fleet must deliver >= 2.5x the single-replica \
         throughput on a saturating trace, got {speedup:.2}x \
         ({:.0} vs {:.0} tok/s)",
        four.serve.throughput_tps(),
        one.serve.throughput_tps()
    );
    let p95_one = one.serve.ttft_summary().expect("ttft").p95();
    let p95_four = four.serve.ttft_summary().expect("ttft").p95();
    assert!(
        p95_four < p95_one,
        "4 replicas must strictly shorten the admission queue: p95 \
         TTFT {:.2} ms !< {:.2} ms",
        p95_four * 1e3,
        p95_one * 1e3
    );
    rec.record_value("self_check_speedup", Value::num(speedup));
    rec.record_value("self_check_ttft_p95_lower", Value::from(true));

    // ---- affinity: class-aware routing vs jsq, equal token counts ---
    let arm = |route| {
        let mut cfg = fleet_cfg(4, route);
        cfg.priority_classes = 4;
        cfg.class_shift = true;
        cfg.replica_profiles = true;
        replay_fleet(&cfg).expect("affinity-arm replay")
    };
    let jsq = arm(FleetRoutePolicy::Jsq);
    let aff = arm(FleetRoutePolicy::Affinity);
    row(&mut table, "affinity/jsq", &jsq);
    row(&mut table, "affinity/affinity", &aff);
    rec.record_value("affinity/jsq", report_json(&jsq));
    rec.record_value("affinity/affinity", report_json(&aff));

    assert_eq!(
        jsq.serve.generated_tokens, aff.serve.generated_tokens,
        "the cross-bytes comparison is only meaningful at equal \
         completed token counts"
    );
    assert!(
        aff.comm.cross_bytes < jsq.comm.cross_bytes,
        "placement-affinity routing must move strictly fewer \
         cross-node bytes than jsq over class-specialised replicas: \
         {:.2} MB !< {:.2} MB",
        aff.comm.cross_bytes / 1e6,
        jsq.comm.cross_bytes / 1e6
    );
    rec.record_value(
        "self_check_affinity_cross_bytes",
        Value::object(vec![
            ("jsq", Value::num(jsq.comm.cross_bytes)),
            ("affinity", Value::num(aff.comm.cross_bytes)),
            ("saved_frac",
             Value::num(1.0 - aff.comm.cross_bytes
                 / jsq.comm.cross_bytes)),
        ]),
    );

    println!("{}", table.render());

    // Wall-clock of the fleet machinery itself (routing, interleave,
    // per-shard pricing) — the scale-out overhead per replay.
    let r = bench("fleet replay (4 replicas, 96 reqs)", 2, 5, || {
        replay_fleet(&fleet_cfg(4, FleetRoutePolicy::Jsq))
            .expect("bench replay")
    });
    println!("{}", r.report_line());
    rec.record(&r);
    if let Some(path) = rec.finish().expect("write bench json") {
        println!("wrote {}", path.display());
    }
}
