//! Figure 6 — cross-dataset generalization of offline placements.
//!
//! Placements are profiled on one dataset profile (text/math/code/mixed)
//! and served against each single-profile workload; the paper reports ≤
//! ~4.5% worst-case regression vs in-domain placement while staying ≥12%
//! ahead of Occult.
//!
//! Run: `cargo bench --bench fig6_generalization`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::sim::{build_placement, simulate,
                             simulate_with_placement, SimConfig};
use grace_moe::trace::Profile;

fn main() {
    let sys = SystemSpec::grace(0.15);
    let sources = [Profile::Text, Profile::Math, Profile::Code,
                   Profile::Mixed];
    let targets = Profile::ALL;

    let mut worst_regression: f64 = 0.0;
    let mut worst_vs_occult: f64 = f64::INFINITY;
    for model in ModelSpec::all() {
        let mk_cfg = |serve: Profile, place: Profile| {
            let mut cfg = SimConfig::new(
                model.clone(),
                Topology::two_by_two(),
                Workload::heavy_i(),
            );
            cfg.serve_profile = serve;
            cfg.placement_profile = place;
            cfg
        };

        println!("\n=== Fig 6: model={} (e2e ms; rows = placement \
                  source, cols = serving dataset) ===", model.name);
        let mut header = vec!["PLACED ON"];
        let tnames: Vec<String> =
            targets.iter().map(|t| t.name().to_uppercase()).collect();
        header.extend(tnames.iter().map(String::as_str));
        let mut t = Table::new(&header);

        // In-domain reference + Occult reference per target.
        let mut indomain = Vec::new();
        let mut occult = Vec::new();
        for &target in &targets {
            let cfg = mk_cfg(target, target);
            indomain.push(simulate(&sys, &cfg).e2e_time);
            occult.push(simulate(&SystemSpec::occult(), &cfg).e2e_time);
        }

        for &src in &sources {
            let cfg_src = mk_cfg(targets[0], src);
            let placement = build_placement(&sys, &cfg_src);
            let mut cells = vec![src.name().to_string()];
            for (i, &target) in targets.iter().enumerate() {
                let cfg = mk_cfg(target, src);
                let m = simulate_with_placement(&sys, &cfg, &placement);
                let reg = m.e2e_time / indomain[i] - 1.0;
                let vs_occ = 1.0 - m.e2e_time / occult[i];
                if src != target {
                    worst_regression = worst_regression.max(reg);
                }
                worst_vs_occult = worst_vs_occult.min(vs_occ);
                cells.push(format!(
                    "{:.1} ({:+.1}%)",
                    m.e2e_time * 1e3,
                    reg * 100.0
                ));
            }
            t.row(cells);
        }
        println!("{}", t.render());
    }

    println!("\nworst cross-dataset regression vs in-domain: {:+.2}% \
              (paper: ≤ +4.52%)", worst_regression * 100.0);
    println!("worst advantage vs Occult: {:.2}% lower latency \
              (paper: ≥ 12.06% on average)", worst_vs_occult * 100.0);
}
