//! Preemption bench: priority scheduling with and without eviction on
//! an identical saturating mixed trace, over the real scheduler on a
//! virtual clock.
//!
//! The workload interleaves a latency-critical class 0 (short prompts,
//! short generations, every 4th request) with a bulk class 1 (long
//! prompts, long generations) arriving faster than the fleet drains.
//! Both arms admit by priority; they differ in exactly one bit,
//! `SchedConfig::preempt`:
//!
//! * **no-preempt** — a class-0 arrival waits for a live class-1 decode
//!   to retire naturally before it gets a slot;
//! * **preempt** — the scheduler evicts the deepest lower-priority
//!   decode on the spot and re-admits it later (KV retained, so the
//!   victim resumes where it left off).
//!
//! Self-checked on every run: the preempt arm's class-0 p95 TTFT is
//! *strictly* below the no-preempt arm's, the preempt arm actually
//! preempted, and every request decodes token-for-token identically in
//! both arms (eviction must never change outputs, only timing).
//!
//! Run: `cargo bench --bench preemption`
//! JSON archive: `cargo bench --bench preemption -- --json`, or
//! `BENCH_JSON=<dir>` (the `make bench-record` path) — writes
//! `BENCH_preemption.json` with both arms plus the self-check verdicts.

use grace_moe::bench::{bench, JsonRecorder, Table};
use grace_moe::configio::Value;
use grace_moe::metrics::ServeMetrics;
use grace_moe::server::sched::{simulate_serve, SchedConfig};
use grace_moe::server::{Request, Response};
use grace_moe::stats::Rng;
use grace_moe::testutil::fake_decode_token as fake_next;

const CTX: usize = 96;
const LAYERS: usize = 4;
const TILE_T: usize = 16;
/// Per-dispatch-round launch overhead, seconds (collective latency
/// floor).
const ROUND_S: f64 = 200e-6;
/// Per-token expert+dense compute, seconds.
const TOKEN_S: f64 = 40e-6;

/// Requests in the mixed trace.
const N_REQUESTS: usize = 48;
/// Poisson arrival rate, req/s — chosen above the drain rate so the
/// fleet saturates and the admission queue stays non-empty.
const RATE: f64 = 400.0;

/// Every 4th request is latency-critical (class 0): short prompt, short
/// generation. The rest are bulk class 1: long prompt, long generation,
/// so each holds its slot for many decode steps.
fn requests() -> Vec<Request> {
    (0..N_REQUESTS)
        .map(|i| {
            let high = i % 4 == 0;
            let prompt_len = if high { 8 } else { 24 };
            Request {
                id: i as u64,
                prompt: (0..prompt_len)
                    .map(|p| ((i * 131 + p * 17) % 512) as i32)
                    .collect(),
                max_new_tokens: if high { 8 } else { 48 },
                priority: if high { 0 } else { 1 },
            }
        })
        .collect()
}

/// Shared Poisson arrival times (same seed in both arms — the traces
/// are identical by construction).
fn arrival_times(seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..N_REQUESTS)
        .map(|_| {
            t += -(1.0 - rng.f64()).ln() / RATE;
            t
        })
        .collect()
}

/// One serving run with preemption on or off; everything else is
/// identical across arms.
fn run_arm(preempt: bool) -> (Vec<Response>, ServeMetrics) {
    let arrivals: Vec<(Request, f64)> =
        requests().into_iter().zip(arrival_times(7)).collect();
    let cfg = SchedConfig {
        max_batch: 8,
        max_batch_tokens: 48,
        ctx: CTX,
        preempt,
        ..SchedConfig::default()
    };
    simulate_serve(
        cfg,
        arrivals,
        |seqs| {
            // KV-cached pricing: compute only each uncached suffix.
            let computed: usize = seqs
                .iter()
                .map(|&(_, ids, cached)| ids.len() - cached)
                .sum();
            let rounds = LAYERS * computed.div_ceil(TILE_T);
            let next =
                seqs.iter().map(|&(_, ids, _)| fake_next(ids)).collect();
            Ok((next, rounds))
        },
        |tokens, rounds| {
            rounds as f64 * ROUND_S + tokens as f64 * TOKEN_S
        },
    )
    .expect("serving run")
}

fn main() {
    let mut rec = JsonRecorder::from_env("preemption");
    let mut table = Table::new(&[
        "ARM",
        "PREEMPTIONS",
        "RESUMES",
        "TTFT-C0 p50 (ms)",
        "TTFT-C0 p95 (ms)",
        "TTFT-C1 p95 (ms)",
        "TOK/S",
    ]);

    let mut arms = Vec::new();
    for (name, preempt) in [("no-preempt", false), ("preempt", true)] {
        let (responses, m) = run_arm(preempt);
        let c0 = m.ttft_summary_class(0).expect("class-0 ttft");
        let c1 = m.ttft_summary_class(1).expect("class-1 ttft");
        table.row(vec![
            name.to_string(),
            format!("{}", m.preemptions),
            format!("{}", m.resumes),
            format!("{:.2}", c0.p50() * 1e3),
            format!("{:.2}", c0.p95() * 1e3),
            format!("{:.2}", c1.p95() * 1e3),
            format!("{:.0}", m.throughput_tps()),
        ]);
        rec.record_value(
            name,
            Value::object(vec![
                ("preemptions", Value::from(m.preemptions)),
                ("resumes", Value::from(m.resumes)),
                ("ttft_p50_class0_ms", Value::num(c0.p50() * 1e3)),
                ("ttft_p95_class0_ms", Value::num(c0.p95() * 1e3)),
                ("ttft_p95_class1_ms", Value::num(c1.p95() * 1e3)),
                ("throughput_tps", Value::num(m.throughput_tps())),
            ]),
        );
        arms.push((responses, m));
    }

    // Self-check 1 — the acceptance bar: with preemption, the
    // latency-critical class's p95 TTFT is strictly better than waiting
    // for natural retirements.
    let p95_off =
        arms[0].1.ttft_summary_class(0).expect("off c0").p95();
    let p95_on = arms[1].1.ttft_summary_class(0).expect("on c0").p95();
    assert!(
        p95_on < p95_off,
        "class-0 p95 TTFT: preempt {:.3} ms !< no-preempt {:.3} ms",
        p95_on * 1e3,
        p95_off * 1e3
    );

    // Self-check 2 — the preempt arm actually exercised eviction (a
    // trace too light to trigger it would vacuously pass check 1).
    assert!(
        arms[1].1.preemptions > 0,
        "preempt arm never preempted — trace is not saturating"
    );
    assert_eq!(arms[1].1.resumes, arms[1].1.preemptions,
               "every evicted sequence must resume in a drained run");

    // Self-check 3 — token-for-token parity: eviction and resume must
    // never change any request's decoded tokens, only its timing.
    let by_id = |rs: &[Response]| {
        let mut v: Vec<(u64, Vec<i32>)> =
            rs.iter().map(|r| (r.id, r.tokens.clone())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(
        by_id(&arms[0].0),
        by_id(&arms[1].0),
        "preemption changed decoded tokens"
    );
    rec.record_value("self_check_ttft_p95_class0", Value::from(true));
    rec.record_value("self_check_token_parity", Value::from(true));
    println!("{}", table.render());

    // Wall-clock of the preemption machinery itself (eviction scan,
    // window re-sort, resume bookkeeping) on the saturating trace.
    let r = bench("preemption machinery (48 reqs, saturating)", 2, 20,
                  || run_arm(true));
    println!("{}", r.report_line());
    rec.record(&r);
    if let Some(path) = rec.finish().expect("write bench json") {
        println!("wrote {}", path.display());
    }
}
