//! Cluster-simulator bench: open-loop fleet replay through the
//! analytic communication backend vs the contended discrete-event
//! network (`comm::sim`), at a quiet and a saturating Poisson arrival
//! rate, on one 2×2 testbed.
//!
//! Both arms run [`grace_moe::engine::replay_fleet`] — the same trace,
//! the same scheduler decisions, the same RNG draw order — and differ
//! only in the [`CommBackendKind`]. The contention claim is self-checked
//! on every run:
//!
//! * **quiet** (requests arrive far apart) — links drain between steps,
//!   so the DES mean latency agrees with the analytic closed form
//!   within a pinned relative tolerance;
//! * **saturating** (the whole trace arrives in one burst) — prompt DMA
//!   and dispatch rounds pile onto shared links, so the DES mean
//!   latency strictly exceeds the analytic arm, which by construction
//!   never queues.
//!
//! Run: `cargo bench --bench cluster_sim`
//! JSON archive: `cargo bench --bench cluster_sim -- --json`, or
//! `BENCH_JSON=<dir>` (the `make bench-record` path) — writes
//! `BENCH_cluster_sim.json` with both arms of both rates plus the
//! self-check evidence.

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::{bench, JsonRecorder, Table};
use grace_moe::cluster::Topology;
use grace_moe::comm::CommBackendKind;
use grace_moe::config::{ArrivalProcess, ModelSpec, ServeLoad, Workload};
use grace_moe::configio::Value;
use grace_moe::engine::{replay_fleet, FleetConfig, FleetReport,
                        SimConfig};

/// Pinned agreement tolerance for the uncontended arm: at a quiet
/// arrival rate the only DES/analytic divergence is the prompt-DMA
/// occupancy the analytic arm prices at zero, a few µs per request.
const QUIET_REL_TOL: f64 = 0.10;

fn fleet_cfg(backend: CommBackendKind, rate: f64) -> FleetConfig {
    let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
    let mut sim = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload { batch: 8, prefill: 8, decode: 2 },
    );
    sim.profile_tokens = 512;
    sim.max_chunk = 512;
    sim.comm_backend = backend;
    let load = ServeLoad {
        requests: 24,
        prompt: 12,
        new_tokens: 4,
        arrival: ArrivalProcess::Poisson { rate },
    };
    let mut cfg = FleetConfig::new(SystemSpec::grace(0.15), sim, load);
    cfg.max_batch = 8;
    cfg.max_batch_tokens = 128;
    cfg
}

fn run(backend: CommBackendKind, rate: f64) -> FleetReport {
    replay_fleet(&fleet_cfg(backend, rate))
        .expect("fleet replay")
}

fn arm_value(rep: &FleetReport) -> Value {
    let lat = rep.serve.latency_summary().expect("latencies");
    let mut fields = vec![
        ("latency_mean_s", Value::num(lat.mean())),
        ("latency_p99_s", Value::num(lat.p99())),
        ("wall_time_s", Value::num(rep.serve.wall_time)),
        ("throughput_tps", Value::num(rep.serve.throughput_tps())),
        ("a2a_time_s", Value::num(rep.comm.time)),
    ];
    if let Some(c) = &rep.contention {
        fields.push(("max_utilization", Value::num(c.max_utilization)));
        fields.push(("queued_wait_s", Value::num(c.queued_wait_s)));
        fields.push(("straggler_stall_s",
                     Value::num(c.straggler_stall_s)));
        fields.push(("event_digest",
                     Value::str(format!("{:016x}", c.event_digest))));
    }
    Value::object(fields)
}

fn main() {
    let mut rec = JsonRecorder::from_env("cluster_sim");
    let mut table = Table::new(&[
        "ARRIVAL",
        "BACKEND",
        "LAT mean (ms)",
        "LAT p99 (ms)",
        "TOK/S",
        "MAX UTIL",
        "QUEUED (ms)",
    ]);

    // (label, Poisson rate): quiet keeps >200 ms between arrivals;
    // saturating lands the whole 24-request trace in a sub-ms burst.
    let rates = [("quiet-4rps", 4.0), ("burst-100krps", 1e5)];
    let mut means = Vec::new();
    for (label, rate) in rates {
        let mut per_backend = Vec::new();
        for backend in
            [CommBackendKind::Analytic, CommBackendKind::Des]
        {
            let rep = run(backend, rate);
            let lat = rep.serve.latency_summary().expect("latencies");
            let (util, queued) = rep
                .contention
                .as_ref()
                .map(|c| (format!("{:.3}", c.max_utilization),
                          format!("{:.3}", c.queued_wait_s * 1e3)))
                .unwrap_or_else(|| ("-".into(), "-".into()));
            table.row(vec![
                label.to_string(),
                backend.name().to_string(),
                format!("{:.3}", lat.mean() * 1e3),
                format!("{:.3}", lat.p99() * 1e3),
                format!("{:.0}", rep.serve.throughput_tps()),
                util,
                queued,
            ]);
            rec.record_value(&format!("{}/{}", label, backend.name()),
                             arm_value(&rep));
            per_backend.push(lat.mean());
        }
        means.push((label, per_backend[0], per_backend[1]));
    }
    println!("{}", table.render());

    // Self-check, the PR-7 acceptance bar. The DES never finishes a
    // transfer earlier than the uncontended closed form, so the only
    // question is how much queueing each rate induces.
    let (_, quiet_ana, quiet_des) = means[0];
    let (_, burst_ana, burst_des) = means[1];
    let quiet_rel = (quiet_des - quiet_ana) / quiet_ana;
    assert!(
        quiet_rel.abs() <= QUIET_REL_TOL,
        "quiet arm disagrees: analytic {quiet_ana:.6}s vs DES \
         {quiet_des:.6}s (rel {quiet_rel:.4} > {QUIET_REL_TOL})"
    );
    assert!(
        burst_des > burst_ana,
        "saturating arm shows no contention: analytic {burst_ana:.6}s \
         !< DES {burst_des:.6}s"
    );
    println!(
        "self-check ok: quiet DES within {:.2}% of analytic, \
         burst DES {:.2}% above analytic",
        quiet_rel.abs() * 1e2,
        (burst_des - burst_ana) / burst_ana * 1e2
    );
    rec.record_value("self_check", Value::object(vec![
        ("quiet_rel_err", Value::num(quiet_rel)),
        ("burst_des_over_analytic",
         Value::num((burst_des - burst_ana) / burst_ana)),
        ("quiet_rel_tol", Value::num(QUIET_REL_TOL)),
        ("passed", Value::from(true)),
    ]));

    // Wall-clock of the simulator machinery itself: one full DES fleet
    // replay (24 requests, 2 MoE layers, contended network).
    let r = bench("DES fleet replay (24 reqs, 2x2 testbed)", 1, 10,
                  || run(CommBackendKind::Des, 1e5));
    println!("{}", r.report_line());
    rec.record(&r);
    if let Some(path) = rec.finish().expect("write bench json") {
        println!("wrote {}", path.display());
    }
}
