//! Figure 1 — the communication / load-balance trade-off that motivates
//! GRACE-MoE (OLMoE, 2 nodes × 2 GPUs/node).
//!
//! (a) grouping uniformity constraint vs cross-device traffic and load
//!     imbalance: Vanilla vs C2R(uniform) vs HG(r sweep) vs fully
//!     non-uniform. Expected shape: relaxing uniformity reduces traffic
//!     but inflates the GPU-load std.
//! (b) number of replicated experts (Rep-Act-x on top of HG) vs load
//!     balance: a few replicas help a lot, then returns diminish.
//!
//! Run: `cargo bench --bench fig1_tradeoff`

use grace_moe::baselines::{GroupingStrategy, SystemSpec};
use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::simulate;
use grace_moe::engine::sim::SimConfig;
use grace_moe::placement::ReplicationMode;
use grace_moe::profile::ModelProfile;
use grace_moe::replication::predict_loads;
use grace_moe::routing::RoutingPolicy;
use grace_moe::stats::{Rng, Summary};
use grace_moe::trace::TraceGen;

fn main() {
    let cfg = SimConfig::new(
        ModelSpec::olmoe(),
        Topology::two_by_two(),
        Workload::heavy_i(),
    );

    // ---- (a) uniformity constraint sweep -------------------------------
    println!("=== Fig 1a: grouping uniformity vs traffic & imbalance ===");
    let mut t = Table::new(&[
        "GROUPING",
        "CROSS (GB)",
        "INTRA (GB)",
        "A2A (ms)",
        "LOAD STD",
    ]);
    let variants: Vec<(&str, SystemSpec)> = vec![
        ("vanilla", SystemSpec::vanilla()),
        ("c2r(uniform)", SystemSpec::c2r()),
        ("uniform+hsc", {
            let mut s = SystemSpec::occult();
            s.comm = grace_moe::comm::CommModel::Hsc;
            s
        }),
        ("hg(r=0.05)", hg(0.05)),
        ("hg(r=0.15)", hg(0.15)),
        ("hg(r=0.40)", hg(0.40)),
        ("fully-non-uniform", {
            let mut s = hg(0.15);
            s.grouping = GroupingStrategy::FullyNonUniform;
            s.name = "fully";
            s
        }),
    ];
    for (label, sys) in &variants {
        let m = simulate(sys, &cfg);
        t.row(vec![
            label.to_string(),
            format!("{:.3}", m.cross_bytes / 1e9),
            format!("{:.3}", m.intra_bytes / 1e9),
            format!("{:.2}", m.a2a_time * 1e3),
            format!("{:.1}", m.mean_load_std()),
        ]);
    }
    println!("{}", t.render());

    // ---- (b) Rep-Act-x sweep -------------------------------------------
    // Replicate the x most-activated experts of each layer's heaviest HG
    // group onto every other GPU and report the predicted load balance
    // (the paper's Fig 1b uses the same predicted-load machinery as §4.3).
    println!("=== Fig 1b: # replicated experts vs load balance ===");
    let trace = TraceGen {
        experts: 64,
        top_k: 8,
        layers: 16,
        profile: grace_moe::trace::Profile::Text,
        seed: 42,
    }
    .generate(2048);
    let profile = ModelProfile::from_trace(&trace);
    let mut rng = Rng::new(1);
    let mut t = Table::new(&["REP-ACT-x", "MEAN GROUP-LOAD STD",
                             "PEAK/MEAN"]);
    for x in [0usize, 1, 2, 4, 8, 12, 16] {
        let mut stds = Vec::new();
        let mut skews = Vec::new();
        for lp in &profile.layers {
            let groups =
                grace_moe::grouping::hierarchical(lp, &cfg.topo, 0.15,
                                                  &mut rng);
            let loads: Vec<f64> =
                groups.iter().map(|g| lp.group_load(g)).collect();
            let heavy = lp.heaviest_group(&groups);
            // Rep-Act-x: top-x experts of the heaviest group, one replica
            // on every other GPU.
            let mut ranked = groups[heavy].clone();
            ranked.sort_by(|&a, &b| {
                lp.load[b].partial_cmp(&lp.load[a]).unwrap()
            });
            let hot: Vec<usize> =
                ranked.into_iter().take(x).collect();
            let w_r: f64 = hot.iter().map(|&e| lp.load[e]).sum();
            let n_rep = loads.len() - 1;
            let rep = grace_moe::replication::Replication {
                hot_experts: hot,
                replica_gpus: (0..loads.len())
                    .filter(|&g| g != heavy)
                    .collect(),
                n_replica: n_rep,
                w_max: loads[heavy],
                w_r,
                computed: true,
            };
            let post = predict_loads(&loads, heavy, &rep);
            let s = Summary::of(&post);
            stds.push(s.std());
            skews.push(s.max() / s.mean());
        }
        t.row(vec![
            format!("{x}"),
            format!("{:.1}", Summary::of(&stds).mean()),
            format!("{:.3}", Summary::of(&skews).mean()),
        ]);
    }
    println!("{}", t.render());
    println!("(expected: sharp improvement for small x, then plateau — \
              moderate replication suffices)");
}

fn hg(r: f64) -> SystemSpec {
    let mut s = SystemSpec::grace(r);
    s.replication = ReplicationMode::None;
    s.routing = RoutingPolicy::Primary;
    s.name = "hg";
    s
}
