//! Table 1 / Figure 5 / Figure 8 — component analysis.
//!
//! Incremental ladder Occult → Occult+HSC → HG+HSC → +FR+WRR → +DR+WRR →
//! +DR+TAR on 2 nodes × 2 GPUs/node, workload (i), averaged over the three
//! models, reported as relative changes vs Occult (Table 1), as
//! end-to-end latency / MoE-layer time (Fig 5), and as absolute metric
//! values (Fig 8).
//!
//! Expected shape: HSC cuts A2A time / cross traffic and raises intra
//! traffic; HG cuts communication further but inflates idle time and load
//! std; DR+WRR recovers idle/load; TAR trims the traffic DR+WRR added at
//! a small idle/std cost; the full ladder beats Occult end-to-end
//! (paper: 1.45× / 1.31× / 1.31×).
//!
//! Run: `cargo bench --bench tab1_components`

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::simulate;
use grace_moe::engine::sim::SimConfig;
use grace_moe::metrics::RunMetrics;
use grace_moe::report;

fn main() {
    let ladder = SystemSpec::table1_ladder(0.15);
    let names: Vec<&str> = ladder.iter().map(|s| s.name).collect();
    let models = ModelSpec::all();

    // Per-model runs (Fig 8 absolute values) + model-averaged Table 1.
    let mut averaged: Vec<RunMetrics> =
        (0..ladder.len()).map(|_| RunMetrics::default()).collect();
    for model in &models {
        let cfg = SimConfig::new(
            model.clone(),
            Topology::two_by_two(),
            Workload::heavy_i(),
        );
        let runs: Vec<RunMetrics> =
            ladder.iter().map(|s| simulate(s, &cfg)).collect();
        println!("\n=== Fig 8 (absolute): model={} ===", model.name);
        println!("{}", report::e2e_table(&names, &runs).render());
        for (acc, r) in averaged.iter_mut().zip(&runs) {
            acc.accumulate(r);
        }
    }

    println!("\n=== Table 1: relative to Occult, averaged over models ===");
    println!("{}", report::table1(&names, &averaged).render());

    println!("=== Fig 5: end-to-end speedup of the full ladder vs Occult \
              (paper: 1.45x / 1.31x / 1.31x) ===");
    for model in &models {
        let cfg = SimConfig::new(
            model.clone(),
            Topology::two_by_two(),
            Workload::heavy_i(),
        );
        let occ = simulate(&ladder[0], &cfg);
        let full = simulate(&ladder[5], &cfg);
        println!(
            "  {:<10} {:.2}x  (moe layer {:.2}x)",
            model.name,
            occ.e2e_time / full.e2e_time,
            occ.moe_layer_time / full.moe_layer_time
        );
    }
}
