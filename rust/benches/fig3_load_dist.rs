//! Figure 3 — computational load distribution after hierarchical
//! grouping (OLMoE).
//!
//! (a) group-level load across layers: affinity clustering concentrates
//!     load on a few groups per layer.
//! (b) per-expert load within the heaviest group of one layer: the
//!     overload stems from a handful of frequently-activated experts.
//!
//! Run: `cargo bench --bench fig3_load_dist`

use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::profile::ModelProfile;
use grace_moe::stats::Rng;
use grace_moe::trace::{Profile, TraceGen};

fn main() {
    let topo = Topology::two_by_two();
    let trace = TraceGen {
        experts: 64,
        top_k: 8,
        layers: 16,
        profile: Profile::Text,
        seed: 42,
    }
    .generate(2048);
    let profile = ModelProfile::from_trace(&trace);
    let mut rng = Rng::new(7);

    println!("=== Fig 3a: per-group load share across layers (HG) ===");
    let mut t = Table::new(&["LAYER", "G0%", "G1%", "G2%", "G3%",
                             "SKEW ρ"]);
    let mut heaviest_per_layer = Vec::new();
    for (l, lp) in profile.layers.iter().enumerate() {
        let groups =
            grace_moe::grouping::hierarchical(lp, &topo, 0.15, &mut rng);
        let loads: Vec<f64> =
            groups.iter().map(|g| lp.group_load(g)).collect();
        let total: f64 = loads.iter().sum();
        let mut shares: Vec<f64> =
            loads.iter().map(|w| w / total * 100.0).collect();
        let rho = lp.load_skew(&groups);
        let heavy = lp.heaviest_group(&groups);
        heaviest_per_layer.push((l, groups[heavy].clone()));
        shares.sort_by(|a, b| b.partial_cmp(a).unwrap());
        t.row(vec![
            format!("{l}"),
            format!("{:.1}", shares[0]),
            format!("{:.1}", shares[1]),
            format!("{:.1}", shares[2]),
            format!("{:.1}", shares[3]),
            format!("{:.2}", rho),
        ]);
    }
    println!("{}", t.render());
    println!("(expected: the top group carries disproportionate load; \
              ρ > 1 in every layer)\n");

    println!("=== Fig 3b: per-expert load inside the heaviest group \
              (layer 5) ===");
    let (l, group) = &heaviest_per_layer[5];
    let lp = &profile.layers[*l];
    let mut ranked = group.clone();
    ranked.sort_by(|&a, &b| lp.load[b].partial_cmp(&lp.load[a]).unwrap());
    let gload: f64 = ranked.iter().map(|&e| lp.load[e]).sum();
    let mut t = Table::new(&["RANK", "EXPERT", "LOAD", "SHARE%",
                             "CUM%"]);
    let mut cum = 0.0;
    for (rank, &e) in ranked.iter().enumerate() {
        cum += lp.load[e];
        t.row(vec![
            format!("{rank}"),
            format!("{e}"),
            format!("{:.0}", lp.load[e]),
            format!("{:.1}", lp.load[e] / gload * 100.0),
            format!("{:.1}", cum / gload * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!("(expected: a few experts dominate the group's load — the \
              replication targets of §4.2)");
}
