//! KV-cache bench: cached incremental decode vs full recompute on
//! identical workloads, over the real scheduler on a virtual clock.
//!
//! Both arms run [`grace_moe::server::sched::simulate_serve_with`] —
//! the same state machine the execute-mode server drives — against a
//! [`grace_moe::testutil::FakeKvEngine`] whose cost model follows the
//! real packing rule (`layers × ⌈computed/tile_t⌉` dispatch rounds per
//! step) and whose decoded tokens are a pure function of the prefix.
//! The arms differ only in `SchedConfig::kv_cache`:
//!
//! * **recompute** (`--kv-cache off` in the server) re-feeds every
//!   live prefix through the stack each step — a step costs
//!   `Σ len(seq)` tokens;
//! * **cached** (the default) prices a sequence at its uncached
//!   suffix — the prompt once at prefill, then exactly **one token per
//!   live sequence per decode step**.
//!
//! Self-checks on every run (the PR's acceptance bar): token-for-token
//! output parity between the arms, the exact 1-token decode-step
//! pricing (`computed = requests × (prompt + new − 1)`), and strictly
//! fewer dispatch rounds per generated token with the cache on.
//!
//! Run: `cargo bench --bench kv_cache`

use grace_moe::bench::{bench, Table};
use grace_moe::config::{ArrivalProcess, ServeLoad};
use grace_moe::server::sched::{simulate_serve_with, SchedConfig,
                               SchedMode};
use grace_moe::server::Request;
use grace_moe::stats::Rng;
use grace_moe::testutil::FakeKvEngine;
use std::cell::RefCell;

const CTX: usize = 64;
const LAYERS: usize = 4;
const TILE_T: usize = 16;
/// Per-dispatch-round launch overhead, seconds (collective latency
/// floor).
const ROUND_S: f64 = 200e-6;
/// Per-token expert+dense compute, seconds.
const TOKEN_S: f64 = 40e-6;

fn requests(load: &ServeLoad) -> Vec<Request> {
    (0..load.requests)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..load.prompt)
                .map(|p| ((i * 131 + p * 17) % 512) as i32)
                .collect(),
            max_new_tokens: load.new_tokens,
            priority: 0,
        })
        .collect()
}

/// One serving run of the configured arm: returns its responses and
/// metrics.
fn run_arm(load: &ServeLoad, kv: bool, seed: u64)
           -> (Vec<grace_moe::server::Response>,
               grace_moe::metrics::ServeMetrics) {
    let mut rng = Rng::new(seed);
    let times = load.arrival_times(&mut rng);
    let arrivals: Vec<(Request, f64)> =
        requests(load).into_iter().zip(times).collect();
    let cfg = SchedConfig {
        mode: SchedMode::Continuous,
        max_batch: 8,
        max_batch_tokens: 4 * CTX,
        ctx: CTX,
        kv_cache: kv,
        ..SchedConfig::default()
    };
    let engine = RefCell::new(FakeKvEngine::new(LAYERS, TILE_T, kv));
    let out = simulate_serve_with(
        cfg,
        arrivals,
        |seqs| engine.borrow_mut().step(seqs),
        |tokens, rounds| {
            rounds as f64 * ROUND_S + tokens as f64 * TOKEN_S
        },
        |id| engine.borrow_mut().retire(id),
    )
    .expect("serving run");
    assert_eq!(engine.borrow().live_caches(), 0,
               "caches must all be evicted by the end of the run");
    out
}

fn main() {
    let loads = [
        ServeLoad {
            requests: 64,
            prompt: 12,
            new_tokens: 16,
            arrival: ArrivalProcess::Closed,
        },
        ServeLoad {
            requests: 64,
            prompt: 12,
            new_tokens: 16,
            arrival: ArrivalProcess::Poisson { rate: 24.0 },
        },
        ServeLoad {
            requests: 96,
            prompt: 24,
            new_tokens: 8,
            arrival: ArrivalProcess::Poisson { rate: 48.0 },
        },
    ];

    let mut table = Table::new(&[
        "WORKLOAD",
        "KV",
        "COMPUTED",
        "CACHED",
        "HIT%",
        "ROUNDS",
        "ROUNDS/TOK",
        "TTFT p50 (ms)",
        "TTFT p95 (ms)",
        "TOK/S",
    ]);

    for load in &loads {
        let mut per_arm = Vec::new();
        for (name, kv) in [("recompute", false), ("cached", true)] {
            let (responses, m) = run_arm(load, kv, 7);
            let ttft = m.ttft_summary().expect("ttft");
            table.row(vec![
                load.label(),
                name.to_string(),
                format!("{}", m.computed_tokens),
                format!("{}", m.cached_tokens),
                format!("{:.0}", m.cache_hit_rate() * 100.0),
                format!("{}", m.dispatch_rounds),
                format!("{:.2}", m.rounds_per_token()),
                format!("{:.1}", ttft.p50() * 1e3),
                format!("{:.1}", ttft.p95() * 1e3),
                format!("{:.0}", m.throughput_tps()),
            ]);
            per_arm.push((responses, m));
        }
        let (re, kv) = (&per_arm[0], &per_arm[1]);

        // Self-check 1 — the headline invariant: cached decode is
        // token-for-token identical to full recompute.
        assert_eq!(re.0.len(), kv.0.len());
        for (a, b) in re.0.iter().zip(&kv.0) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens,
                       "{}: request {} tokens diverged across arms",
                       load.label(), a.id);
        }

        // Self-check 2 — exact decode pricing: with the cache on, each
        // sequence is computed as its prompt once (prefill) and then
        // exactly one token per decode step.
        let want =
            load.requests * (load.prompt + load.new_tokens - 1);
        assert_eq!(
            kv.1.computed_tokens, want,
            "{}: cached arm computed {} tokens, expected \
             requests×(prompt+new−1) = {}",
            load.label(), kv.1.computed_tokens, want
        );
        assert_eq!(re.1.cached_tokens, 0);

        // Self-check 3 — the density win: strictly fewer dispatch
        // rounds per generated token with the cache on.
        assert!(
            kv.1.rounds_per_token() < re.1.rounds_per_token(),
            "{}: cached {} rounds/tok !< recompute {}",
            load.label(),
            kv.1.rounds_per_token(),
            re.1.rounds_per_token()
        );
    }
    println!("{}", table.render());

    // Wall-clock of the cached-arm scheduler machinery (admission,
    // suffix pricing, cache bookkeeping, retirement).
    let load = loads[0];
    let r = bench("kv-cached scheduling (64 reqs, closed loop)", 2, 30,
                  || run_arm(&load, true, 7));
    println!("{}", r.report_line());
}
