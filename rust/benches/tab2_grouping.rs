//! Table 2 / Appendix A.1 — validation of the non-uniform ratio
//! selection.
//!
//! Part 1: the (S(r), U(r)) trade-off curve (Eqs. 1–2) over candidate
//! ratios and the knee point the selector picks.
//! Part 2: the Table-2 comparison — uniform (Occult) vs controlled
//! non-uniform (r = 0.15) vs fully non-uniform — reporting A2A time, GPU
//! idle time, and end-to-end latency on OLMoE, 2×2, workload (i).
//!
//! Expected shape: uniform has the highest A2A time; fully non-uniform
//! shaves a little more A2A than controlled but pays in idle time and
//! loses end-to-end; the knee sits at a small-but-nonzero r.
//!
//! Run: `cargo bench --bench tab2_grouping`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::simulate;
use grace_moe::engine::sim::SimConfig;
use grace_moe::grouping::{select_r, tradeoff_curve};
use grace_moe::profile::ModelProfile;
use grace_moe::stats::Rng;
use grace_moe::trace::{Profile, TraceGen};

fn main() {
    // ---- A.1: the U(r)/S(r) curve and knee selection -------------------
    let trace = TraceGen {
        experts: 64,
        top_k: 8,
        layers: 16,
        profile: Profile::Text,
        seed: 42,
    }
    .generate(2048);
    let profile = ModelProfile::from_trace(&trace);
    let candidates = [0.0, 0.05, 0.1, 0.15, 0.2, 0.3, 0.5, 0.75, 1.0];
    let mut rng = Rng::new(3);

    println!("=== A.1: affinity utilization U(r) vs size deviation S(r) \
              (layer-0 profile, D=4) ===");
    let mut t = Table::new(&["r", "U(r)", "S(r)"]);
    let curve = tradeoff_curve(&profile.layers[0], 4, &candidates,
                               &mut rng);
    for (r, u, s) in &curve {
        t.row(vec![
            format!("{r:.2}"),
            format!("{u:.4}"),
            format!("{s:.3}"),
        ]);
    }
    println!("{}", t.render());
    let knee = select_r(&profile.layers[0], 4, &candidates, &mut rng);
    println!("knee-point selection: r* = {knee}  (paper uses r = 0.15)\n");

    // ---- Table 2 --------------------------------------------------------
    let cfg = SimConfig::new(
        ModelSpec::olmoe(),
        Topology::two_by_two(),
        Workload::heavy_i(),
    );
    println!("=== Table 2: grouping strategies (OLMoE, 2x2, workload i) \
              ===");
    let mut t = Table::new(&[
        "GROUPING",
        "A2A TIME (ms)",
        "IDLE TIME (ms)",
        "E2E (ms)",
    ]);
    for sys in SystemSpec::table2_groupings() {
        let m = simulate(&sys, &cfg);
        t.row(vec![
            sys.name.to_string(),
            format!("{:.2}", m.a2a_time * 1e3),
            format!("{:.2}", m.idle_time * 1e3),
            format!("{:.2}", m.e2e_time * 1e3),
        ]);
    }
    println!("{}", t.render());
    println!("(paper: 3494/502/6328 — 2846/507/5698 — 2826/617/5748 ms; \
              shape to match: uniform worst on A2A, fully-non-uniform \
              worst on idle, controlled best end-to-end)");
}
