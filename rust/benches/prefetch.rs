//! Prefetch bench: the PR-10 acceptance bars, self-checked on every
//! run.
//!
//! Three arms over the weight-tier engine
//! ([`grace_moe::engine::PrefetchEngine`]), all replaying *identical*
//! dispatch plans with prediction on vs off — token output is equal by
//! construction, so every comparison isolates the staging policy:
//!
//! * **correlated** — an 8-expert round-robin trace whose layer-1 hot
//!   set is a deterministic function of layer 0's (expert `e` → expert
//!   `e+1`), under a 2-expert-per-GPU budget that cannot hold both
//!   layers at once. The bar: prediction must stall strictly fewer
//!   layer rounds than demand-only staging at equal token output, and
//!   waste at most 25% of its prefetched bytes (only the final
//!   warm-ahead can retire unused).
//! * **contended** — the same trace priced on the discrete-event
//!   network (`des`): the win must survive real link queueing, and two
//!   replays must agree counter-for-counter (the determinism gate).
//! * **uncorrelated** — layer 1 cycles through experts independently
//!   of layer 0, so every prediction is stale. The bar is graceful
//!   degradation: no more stalled rounds than demand-only staging and
//!   no stall-time blow-up (mispredictions must skip resident keys
//!   instead of thrashing the tier).
//!
//! Run: `cargo bench --bench prefetch`
//! JSON archive: `cargo bench --bench prefetch -- --json`, or
//! `BENCH_JSON=<dir>` (the `make bench-record` path) — writes
//! `BENCH_prefetch.json` with all arms plus the self-check verdicts.

use grace_moe::bench::{bench, JsonRecorder, Table};
use grace_moe::cluster::Topology;
use grace_moe::comm::{CommBackend, CommBackendKind};
use grace_moe::config::PrefetchConfig;
use grace_moe::configio::Value;
use grace_moe::engine::PrefetchEngine;
use grace_moe::linalg::Matrix;
use grace_moe::metrics::PrefetchStats;
use grace_moe::placement::{LayerPlacement, ReplicationMode};
use grace_moe::profile::LayerProfile;
use grace_moe::routing::{Assignment, DispatchPlan, Dispatcher,
                         RoutingPolicy};
use grace_moe::stats::Rng;

const EXPERTS: usize = 8;
const GPUS: usize = 4;
const EXPERT_BYTES: f64 = 1e6;
/// Correlated-arm rounds (each = one pass through both layers).
const ROUNDS: usize = 6;
/// Uncorrelated-arm rounds: two full cycles of the drifting hot set.
const UROUNDS: usize = 16;

/// 8 experts striped over 4 GPUs (GPU `g` owns `g` and `g+4`), no
/// replication: Primary routing sends expert `e` to GPU `e % 4`
/// deterministically.
fn fixture() -> LayerPlacement {
    let profile = LayerProfile {
        affinity: Matrix::zeros(EXPERTS, EXPERTS),
        load: vec![1.0; EXPERTS],
        tokens: EXPERTS,
    };
    let groups = (0..GPUS)
        .map(|g| vec![g, g + GPUS])
        .collect();
    LayerPlacement::build(&profile, groups, ReplicationMode::None)
}

/// Route `sets[t]` (the experts token `t` activates) through the real
/// dispatcher — both arms replay the exact plans this returns.
fn plan_for(lp: &LayerPlacement, layer: usize, sets: &[Vec<usize>])
            -> DispatchPlan {
    let topo = Topology::paper_testbed(1, GPUS);
    let mut d = Dispatcher::new(topo, RoutingPolicy::Primary.build(),
                                1.0);
    let batch: Vec<Assignment> = sets
        .iter()
        .enumerate()
        .flat_map(|(t, es)| {
            es.iter().map(move |&e| Assignment {
                token: t,
                expert: e,
                src: t % GPUS,
            })
        })
        .collect();
    d.dispatch(lp, layer, &batch, &mut Rng::new(5))
}

fn engine(predictive: bool, k: usize) -> PrefetchEngine {
    let cfg = PrefetchConfig {
        predictive,
        k,
        weight_budget: 2,
        alpha: 0.5,
    };
    PrefetchEngine::new(cfg, 2, EXPERTS, GPUS, EXPERT_BYTES)
}

struct Arm {
    stats: PrefetchStats,
    /// Critical-path stall seconds summed over demand passes.
    stall_time: f64,
    /// Routed (token, expert) pairs replayed — the token-output
    /// equality witness.
    pairs: usize,
}

/// The correlated trace: every round layer 0 activates all 8 experts
/// (token `t` → expert `t`) and layer 1 activates the shifted set
/// (token `t` → expert `t+1`), so the cross-layer transition is fully
/// learnable after one round.
fn replay_correlated(predictive: bool, kind: CommBackendKind) -> Arm {
    let lp = fixture();
    let topo = Topology::paper_testbed(1, GPUS);
    let mut backend = CommBackend::new(kind, &topo);
    let mut eng = engine(predictive, EXPERTS);
    let s0: Vec<Vec<usize>> = (0..EXPERTS).map(|t| vec![t]).collect();
    let s1: Vec<Vec<usize>> =
        (0..EXPERTS).map(|t| vec![(t + 1) % EXPERTS]).collect();
    let p0 = plan_for(&lp, 0, &s0);
    let p1 = plan_for(&lp, 1, &s1);
    let mut stall_time = 0.0;
    let mut pairs = 0;
    for round in 0..ROUNDS {
        let at = round as f64 * 1e-3;
        stall_time += eng.demand_pass(0, &p0, &mut backend, &topo, at);
        eng.prefetch_pass(0, &p0, &lp, &mut backend, &topo, at);
        stall_time += eng.demand_pass(1, &p1, &mut backend, &topo, at);
        eng.prefetch_pass(1, &p1, &lp, &mut backend, &topo, at);
        pairs += p0.assignments().len() + p1.assignments().len();
    }
    eng.finish();
    Arm { stats: eng.stats().clone(), stall_time, pairs }
}

/// The uncorrelated trace: layer 0 always activates expert 0 while
/// layer 1 cycles `r % 8` — layer 1's next set is never predictable
/// from layer 0's current one.
fn replay_uncorrelated(predictive: bool) -> Arm {
    let lp = fixture();
    let topo = Topology::paper_testbed(1, GPUS);
    let mut backend = CommBackend::new(CommBackendKind::Analytic, &topo);
    let mut eng = engine(predictive, 2);
    let p0 = plan_for(&lp, 0, &[vec![0]]);
    let mut stall_time = 0.0;
    let mut pairs = 0;
    for round in 0..UROUNDS {
        let at = round as f64 * 1e-3;
        let p1 = plan_for(&lp, 1, &[vec![round % EXPERTS]]);
        stall_time += eng.demand_pass(0, &p0, &mut backend, &topo, at);
        eng.prefetch_pass(0, &p0, &lp, &mut backend, &topo, at);
        stall_time += eng.demand_pass(1, &p1, &mut backend, &topo, at);
        eng.prefetch_pass(1, &p1, &lp, &mut backend, &topo, at);
        pairs += p0.assignments().len() + p1.assignments().len();
    }
    eng.finish();
    Arm { stats: eng.stats().clone(), stall_time, pairs }
}

fn row(table: &mut Table, arm: &str, a: &Arm) {
    table.row(vec![
        arm.to_string(),
        a.stats.stall_steps.to_string(),
        a.stats.stalls.to_string(),
        a.stats.hits.to_string(),
        a.stats.prefetches.to_string(),
        format!("{:.2}", a.stall_time * 1e3),
        format!("{:.2}", a.stats.wasted_bytes / 1e6),
    ]);
}

fn arm_json(a: &Arm) -> Value {
    Value::object(vec![
        ("stall_steps", Value::from(a.stats.stall_steps)),
        ("stalls", Value::from(a.stats.stalls)),
        ("hits", Value::from(a.stats.hits)),
        ("prefetches", Value::from(a.stats.prefetches)),
        ("evictions", Value::from(a.stats.evictions)),
        ("hit_rate", Value::num(a.stats.hit_rate())),
        ("stall_time_ms", Value::num(a.stall_time * 1e3)),
        ("prefetch_bytes", Value::num(a.stats.prefetch_bytes)),
        ("demand_bytes", Value::num(a.stats.demand_bytes)),
        ("wasted_bytes", Value::num(a.stats.wasted_bytes)),
        ("routed_pairs", Value::from(a.pairs)),
    ])
}

fn main() {
    let mut rec = JsonRecorder::from_env("prefetch");
    let mut table = Table::new(&[
        "ARM",
        "STALL ROUNDS",
        "STALLS",
        "HITS",
        "PREFETCHES",
        "STALL (ms)",
        "WASTED MB",
    ]);

    // ---- correlated: prediction must beat demand-only staging -------
    let on = replay_correlated(true, CommBackendKind::Analytic);
    let off = replay_correlated(false, CommBackendKind::Analytic);
    row(&mut table, "correlated/on", &on);
    row(&mut table, "correlated/off", &off);
    rec.record_value("correlated/on", arm_json(&on));
    rec.record_value("correlated/off", arm_json(&off));

    assert_eq!(on.pairs, off.pairs,
               "both arms must replay identical token output");
    assert!(
        on.stats.stall_steps < off.stats.stall_steps,
        "prefetch-on must stall strictly fewer layer rounds than \
         prefetch-off on a correlated trace: {} !< {}",
        on.stats.stall_steps, off.stats.stall_steps
    );
    assert!(on.stall_time < off.stall_time,
            "fewer stalled rounds must mean less critical-path time");
    assert!(on.stats.prefetches > 0, "prediction never fired");
    assert!(
        on.stats.wasted_bytes <= 0.25 * on.stats.prefetch_bytes,
        "wasted prefetch bytes past the pinned fraction: {:.1} MB of \
         {:.1} MB prefetched",
        on.stats.wasted_bytes / 1e6, on.stats.prefetch_bytes / 1e6
    );
    assert_eq!(off.stats.prefetches, 0);
    assert_eq!(off.stats.prefetch_bytes, 0.0);
    rec.record_value(
        "self_check_correlated",
        Value::object(vec![
            ("stall_steps_on", Value::from(on.stats.stall_steps)),
            ("stall_steps_off", Value::from(off.stats.stall_steps)),
            ("wasted_frac",
             Value::num(on.stats.wasted_bytes
                 / on.stats.prefetch_bytes.max(1.0))),
        ]),
    );

    // ---- contended: the win survives the DES network, bit-stably ----
    let des_on = replay_correlated(true, CommBackendKind::Des);
    let des_off = replay_correlated(false, CommBackendKind::Des);
    row(&mut table, "des/on", &des_on);
    row(&mut table, "des/off", &des_off);
    rec.record_value("des/on", arm_json(&des_on));
    rec.record_value("des/off", arm_json(&des_off));

    assert!(des_on.stats.stall_steps < des_off.stats.stall_steps,
            "the prefetch win must survive contended pricing");
    assert!(des_on.stall_time > 0.0 && des_off.stall_time > 0.0,
            "DES stages must take real time");
    let again = replay_correlated(true, CommBackendKind::Des);
    assert_eq!(again.stats, des_on.stats,
               "DES staging counters diverge across reruns");
    assert_eq!(again.stall_time, des_on.stall_time,
               "DES stall timing diverges across reruns");
    rec.record_value("self_check_des_deterministic", Value::from(true));

    // ---- uncorrelated: stale predictions must degrade gracefully ----
    let u_on = replay_uncorrelated(true);
    let u_off = replay_uncorrelated(false);
    row(&mut table, "uncorrelated/on", &u_on);
    row(&mut table, "uncorrelated/off", &u_off);
    rec.record_value("uncorrelated/on", arm_json(&u_on));
    rec.record_value("uncorrelated/off", arm_json(&u_off));

    assert_eq!(u_on.pairs, u_off.pairs);
    assert!(
        u_on.stats.stall_steps <= u_off.stats.stall_steps,
        "an unpredictable trace must not stall more rounds with \
         prediction on: {} > {}",
        u_on.stats.stall_steps, u_off.stats.stall_steps
    );
    assert!(
        u_on.stall_time <= u_off.stall_time * 1.25 + 1e-12,
        "stale predictions blew up stall time: {:.3} ms vs {:.3} ms",
        u_on.stall_time * 1e3, u_off.stall_time * 1e3
    );
    rec.record_value(
        "self_check_uncorrelated",
        Value::object(vec![
            ("stall_steps_on", Value::from(u_on.stats.stall_steps)),
            ("stall_steps_off", Value::from(u_off.stats.stall_steps)),
        ]),
    );

    println!("{}", table.render());

    // Wall-clock of the staging machinery itself (tier bookkeeping,
    // prediction, pricing) — both arms end to end.
    let r = bench("prefetch replay (on+off, analytic)", 2, 5, || {
        let on = replay_correlated(true, CommBackendKind::Analytic);
        let off = replay_correlated(false, CommBackendKind::Analytic);
        assert!(on.stats.stall_steps < off.stats.stall_steps);
    });
    println!("{}", r.report_line());
    rec.record(&r);
    if let Some(path) = rec.finish().expect("write bench json") {
        println!("wrote {}", path.display());
    }
}
