//! Figure 4 — end-to-end inference latency and MoE layer time.
//!
//! Regenerates the paper's headline comparison: GRACE-MoE vs {Vanilla,
//! Tutel, MegaBlocks, vLLM, C2R, Occult} across the three Table-3 models,
//! the two §6.2 workloads, and both cluster scales (2×2, 2×4).
//!
//! Expected shape (the paper's result): GRACE wins everywhere; the gap
//! widens at 2×4 where cross-node pressure grows; maximum speedups in the
//! paper are 4.66× / 3.73× / 4.47× over the weakest baselines.
//!
//! Run: `cargo bench --bench fig4_end_to_end`

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::sim::{build_placement, simulate_with_placement,
                             SimConfig};
use grace_moe::placement::Placement;
use grace_moe::report;
use std::collections::HashMap;

fn main() {
    let models = ModelSpec::all();
    let workloads = [Workload::heavy_i(), Workload::heavy_ii()];
    let clusters =
        [Topology::two_by_two(), Topology::two_by_four()];
    let systems = SystemSpec::fig4_systems(0.15);

    let mut max_speedup: HashMap<&str, f64> = HashMap::new();
    for model in &models {
        for topo in &clusters {
            // Placements depend on (model, topo, grouping strategy) —
            // share them across systems and workloads.
            let mut placements: HashMap<String, Placement> = HashMap::new();
            for workload in &workloads {
                let cfg = SimConfig::new(model.clone(), topo.clone(),
                                         *workload);
                let names: Vec<&str> =
                    systems.iter().map(|s| s.name).collect();
                let runs: Vec<_> = systems
                    .iter()
                    .map(|s| {
                        let key = format!("{:?}{:?}", s.grouping,
                                          s.replication);
                        let p = placements
                            .entry(key)
                            .or_insert_with(|| build_placement(s, &cfg));
                        simulate_with_placement(s, &cfg, p)
                    })
                    .collect();
                println!(
                    "\n=== Fig4: model={} cluster={}x{} workload={} ===",
                    model.name,
                    topo.nodes,
                    topo.gpus_per_node,
                    workload.label()
                );
                println!("{}", report::e2e_table(&names, &runs).render());
                // Track GRACE speedup over the slowest baseline.
                let grace =
                    runs.last().expect("grace is last").e2e_time;
                let worst = runs[..runs.len() - 1]
                    .iter()
                    .map(|m| m.e2e_time)
                    .fold(0.0, f64::max);
                let s = worst / grace;
                let e = max_speedup.entry(model.name).or_insert(0.0);
                if s > *e {
                    *e = s;
                }
            }
        }
    }

    println!("\n=== Fig4 headline: max GRACE speedup per model ===");
    println!("(paper reports up to 4.66x / 3.73x / 4.47x)");
    for model in &models {
        println!("  {:<10} {:.2}x", model.name,
                 max_speedup[model.name]);
    }
}
