//! Design-choice ablations (DESIGN.md §7) — sensitivity of GRACE-MoE to
//! the knobs the paper fixes implicitly:
//!
//! * HSC zero-padding quantum (the "logically sparse slots" granularity),
//! * HSC overlap of cross-node comm with routing compute (on/off),
//! * the progress-decoupling κ of the staged-hierarchical comparator,
//! * knee-selected r vs fixed r values,
//! * profiling-trace length (how much offline profiling is enough).
//!
//! Run: `cargo bench --bench ablations`

use grace_moe::baselines::{GroupingStrategy, SystemSpec};
use grace_moe::bench::Table;
use grace_moe::cluster::Topology;
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::simulate;
use grace_moe::engine::sim::SimConfig;
use grace_moe::grouping::select_r;
use grace_moe::profile::ModelProfile;
use grace_moe::stats::Rng;
use grace_moe::trace::{Profile, TraceGen};

fn cfg() -> SimConfig {
    SimConfig::new(
        ModelSpec::olmoe(),
        Topology::two_by_two(),
        Workload::heavy_i(),
    )
}

fn main() {
    // --- r sensitivity: fixed values vs the knee selector ---------------
    println!("=== ablation: non-uniformity ratio r (GRACE e2e) ===");
    let mut t = Table::new(&["r", "E2E (ms)", "A2A (ms)", "IDLE (ms)"]);
    let base = cfg();
    for r in [0.0, 0.05, 0.15, 0.3, 0.5, 1.0] {
        let m = simulate(&SystemSpec::grace(r), &base);
        t.row(vec![
            format!("{r:.2}"),
            format!("{:.1}", m.e2e_time * 1e3),
            format!("{:.1}", m.a2a_time * 1e3),
            format!("{:.1}", m.idle_time * 1e3),
        ]);
    }
    // knee-selected r on the layer-0 profile
    let trace = TraceGen {
        experts: 64,
        top_k: 8,
        layers: 1,
        profile: Profile::Text,
        seed: 42,
    }
    .generate(2048);
    let profile = ModelProfile::from_trace(&trace);
    let lp = &profile.layers[0];
    let r_star = select_r(lp, 4, &[0.0, 0.05, 0.15, 0.3, 0.5, 1.0],
                          &mut Rng::new(1));
    let m = simulate(&SystemSpec::grace(r_star), &base);
    t.row(vec![
        format!("knee({r_star:.2})"),
        format!("{:.1}", m.e2e_time * 1e3),
        format!("{:.1}", m.a2a_time * 1e3),
        format!("{:.1}", m.idle_time * 1e3),
    ]);
    println!("{}", t.render());

    // --- profiling-trace length ------------------------------------------
    println!("=== ablation: offline profiling length (GRACE e2e) ===");
    let mut t = Table::new(&["PROFILE TOKENS", "E2E (ms)"]);
    for n in [128usize, 512, 2048, 8192] {
        let mut c = cfg();
        c.profile_tokens = n;
        let m = simulate(&SystemSpec::grace(0.15), &c);
        t.row(vec![format!("{n}"), format!("{:.1}", m.e2e_time * 1e3)]);
    }
    println!("{}", t.render());
    println!("(expected: short profiles misplace experts; returns \
              saturate quickly — the paper's offline phase is cheap)\n");

    // --- routing policy × replication interaction -------------------------
    println!("=== ablation: replication × routing matrix (e2e ms) ===");
    use grace_moe::placement::ReplicationMode as RM;
    use grace_moe::routing::RoutingPolicy as RP;
    let mut t = Table::new(&["REPLICATION", "primary", "wrr", "tar",
                             "load-aware"]);
    for (rn, rm) in [("none", RM::None), ("fixed", RM::Fixed),
                     ("dynamic", RM::Dynamic)] {
        let mut cells = vec![rn.to_string()];
        for rp in [RP::Primary, RP::Wrr, RP::Tar, RP::LoadAware] {
            let sys = SystemSpec {
                replication: rm,
                routing: rp,
                grouping: GroupingStrategy::Hierarchical { r: 0.15 },
                ..SystemSpec::grace(0.15)
            };
            let m = simulate(&sys, &base);
            cells.push(format!("{:.1}", m.e2e_time * 1e3));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    println!("(expected: replicas are useless without WRR/TAR to route \
              to them; TAR+dynamic is the corner the paper ships)");
}
