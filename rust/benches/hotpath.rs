//! Hot-path microbenchmarks (the §Perf L3 profile targets).
//!
//! Covers the request-path components: routing decisions (see
//! `routing_dispatch` for the per-policy scalar-vs-batched comparison),
//! traffic-matrix construction, collective cost models, the full
//! per-layer simulation step, offline spectral grouping, and (when
//! artifacts are present) PJRT artifact execution.
//!
//! Run: `cargo bench --bench hotpath`

use grace_moe::baselines::SystemSpec;
use grace_moe::bench::{bench, bench_auto};
use grace_moe::cluster::Topology;
use grace_moe::comm::model::{flat_all_to_all, hsc};
use grace_moe::comm::traffic::{per_copy, two_stage, Dispatch};
use grace_moe::config::{ModelSpec, Workload};
use grace_moe::engine::simulate;
use grace_moe::engine::sim::{build_placement, SimConfig};
use grace_moe::routing::{RouteCtx, RoutingPolicy};
use grace_moe::stats::Rng;

fn main() {
    let topo = Topology::two_by_two();
    let model = ModelSpec::olmoe();
    let cfg = SimConfig::new(model.clone(), topo.clone(),
                             Workload::heavy_i());
    let sys = SystemSpec::grace(0.15);
    let placement = build_placement(&sys, &cfg);

    // ---- routing --------------------------------------------------------
    // One representative row; the full per-policy scalar-vs-batched
    // comparison lives in `cargo bench --bench routing_dispatch`.
    let lp = &placement.layers[0];
    let mut rng = Rng::new(1);
    {
        let mut pol = RoutingPolicy::Tar.build();
        let ctx = RouteCtx { placement: lp, topo: &topo, layer: 0 };
        let r = bench("select 4096x8 (tar)", 3, 30, || {
            let mut acc = 0usize;
            for t in 0..4096usize {
                for k in 0..8usize {
                    acc += pol.select(&ctx, t % 4, (t * 7 + k * 13) % 64,
                                      &mut rng);
                }
            }
            pol.end_round(&ctx);
            acc
        });
        println!("{}", r.report_line());
    }

    // ---- traffic construction + comm models -----------------------------
    let dispatches: Vec<Dispatch> = (0..4096)
        .map(|t| Dispatch {
            src: t % 4,
            dsts: (0..8).map(|k| (t * 7 + k * 13) % 4).collect(),
        })
        .collect();
    let r = bench("traffic per_copy 4096x8", 3, 50, || {
        per_copy(&dispatches, 4, 4096.0)
    });
    println!("{}", r.report_line());
    let r = bench("traffic two_stage 4096x8", 3, 50, || {
        two_stage(&dispatches, &topo, 4096.0)
    });
    println!("{}", r.report_line());

    let m = per_copy(&dispatches, 4, 4096.0);
    let ts = two_stage(&dispatches, &topo, 4096.0);
    let mut rng2 = Rng::new(2);
    let r = bench("comm flat_all_to_all", 3, 200, || {
        flat_all_to_all(&m, &topo, &mut rng2)
    });
    println!("{}", r.report_line());
    let r = bench("comm hsc", 3, 200, || {
        hsc(&ts, &topo, 0.0, &mut rng2)
    });
    println!("{}", r.report_line());

    // ---- end-to-end simulation steps ------------------------------------
    let r = bench_auto("simulate olmoe 2x2 grace (full run)", 2.0, || {
        simulate(&sys, &cfg)
    });
    println!("{}", r.report_line());

    // ---- offline grouping (spectral) -------------------------------------
    let r = bench_auto("build_placement olmoe 16L hierarchical", 3.0, || {
        build_placement(&sys, &cfg)
    });
    println!("{}", r.report_line());

    // ---- replan rollout: instance-table cache ---------------------------
    // A fleet rollout applies one accepted delta to N replica placements.
    // The naive path (`apply_delta` per replica) rebuilds each changed
    // layer's instance table N times; `PreparedDelta` builds it once and
    // clones it into every replica whose primary map still matches. The
    // `instances_build_count` counter pins the allocation counts exactly
    // (this bench is its own process, so no parallel test perturbs it).
    {
        use grace_moe::placement::instances_build_count;
        use grace_moe::replan::{apply_delta, LayerDelta, PreparedDelta,
                                ReplanDelta};

        let lp0 = &placement.layers[0];
        // Force a structural change: replicate the first two experts
        // onto every GPU that hosts neither primary.
        let mut repl = lp0.replication.clone();
        repl.hot_experts = vec![0, 1];
        repl.replica_gpus = (0..placement.num_gpus)
            .filter(|&g| g != lp0.primary[0] && g != lp0.primary[1])
            .collect();
        repl.n_replica = repl.replica_gpus.len();
        repl.computed = true;
        let delta = ReplanDelta {
            layers: vec![LayerDelta {
                layer: 0,
                replication: repl,
                added: Vec::new(),
                removed: Vec::new(),
                predicted: lp0.predicted.clone(),
                polling: lp0.polling.clone(),
                rho_live: 0.0,
                migration_bytes: 0.0,
                benefit_s: 0.0,
                cost_s: 0.0,
            }],
            migration_bytes: 0.0,
            benefit_s: 0.0,
            cost_s: 0.0,
        };
        const REPLICAS: usize = 8;

        let before = instances_build_count();
        let naive: Vec<_> = (0..REPLICAS)
            .map(|_| apply_delta(&placement, &delta))
            .collect();
        let naive_builds = instances_build_count() - before;
        assert_eq!(naive_builds, REPLICAS as u64,
                   "apply_delta must rebuild the changed layer's \
                    instance table once per replica");

        let before = instances_build_count();
        let prep = PreparedDelta::new(&placement, delta.clone());
        let cached: Vec<_> = (0..REPLICAS)
            .map(|_| prep.apply(&placement))
            .collect();
        let cached_builds = instances_build_count() - before;
        assert_eq!(cached_builds, 1,
                   "PreparedDelta must build the changed layer's \
                    instance table exactly once for the whole rollout, \
                    got {cached_builds}");

        for (n, c) in naive.iter().zip(&cached) {
            assert_eq!(n.layers[0].instances, c.layers[0].instances,
                       "cached rollout must equal the naive one");
            assert_ne!(n.layers[0].instances, placement.layers[0].instances,
                       "the bench delta must actually change layer 0");
        }

        // Empty deltas (the common every-epoch case) must not rebuild
        // anything at all.
        let before = instances_build_count();
        let noop = PreparedDelta::new(&placement, ReplanDelta::default());
        assert!(noop.is_empty());
        assert_eq!(instances_build_count() - before, 0,
                   "preparing an empty delta must not touch \
                    instances_for");

        let r = bench("replan rollout apply_delta x8", 3, 50, || {
            (0..REPLICAS)
                .map(|_| apply_delta(&placement, &delta).layers.len())
                .sum::<usize>()
        });
        println!("{}", r.report_line());
        let r = bench("replan rollout PreparedDelta x8", 3, 50, || {
            let prep = PreparedDelta::new(&placement, delta.clone());
            (0..REPLICAS)
                .map(|_| prep.apply(&placement).layers.len())
                .sum::<usize>()
        });
        println!("{}", r.report_line());
    }

    // ---- PJRT execution (needs artifacts + a real PJRT runtime) ---------
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists()
        && grace_moe::runtime::pjrt::runtime_available()
    {
        use grace_moe::engine::real::RealModel;
        let rm = RealModel::load(dir, "olmoe_tiny").expect("load model");
        let c = rm.cfg.clone();
        let x: Vec<f32> = (0..c.tile_t * c.hidden)
            .map(|i| ((i % 17) as f32 - 8.0) * 0.05)
            .collect();
        let r = bench("pjrt gate (64 tokens)", 3, 50, || {
            rm.gate(&x, 0).expect("gate")
        });
        println!("{}", r.report_line());
        let xa = vec![0.1f32; c.cap_rows() * c.hidden];
        let te: Vec<i32> = (0..c.cap_tiles)
            .map(|i| if i < 8 { (i % 4) as i32 } else { -1 })
            .collect();
        let r = bench("pjrt grouped_ffn (cap buffer)", 3, 20, || {
            rm.grouped_ffn(0, &xa, &te).expect("ffn")
        });
        println!("{}", r.report_line());
        let r = bench("pjrt moe_layer_full oracle", 3, 20, || {
            rm.moe_layer_oracle(&x, 0).expect("oracle")
        });
        println!("{}", r.report_line());
    } else {
        println!("(skipping PJRT benches: need `make artifacts` and a \
                  real PJRT runtime — see rust/shims/xla)");
    }
}
