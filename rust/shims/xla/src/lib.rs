//! Std-only stand-in for the `xla` crate (PJRT bindings).
//!
//! The runtime boundary of the engine (`grace_moe::runtime::pjrt`) is
//! written against the xla-rs API surface. The native `xla_extension`
//! runtime cannot be vendored offline, so this crate splits that surface
//! in two:
//!
//! * **[`Literal`] marshalling is real** — shape/dtype-checked host
//!   tensors with `vec1`/`scalar`/`reshape`/`to_vec`/`to_tuple`, enough
//!   for every pure-host code path and its tests,
//! * **the PJRT client is a loud stub** — [`PjRtClient::cpu`] returns an
//!   error that names this file, so execute-mode fails fast with an
//!   actionable message instead of a link error. Execute-mode tests gate
//!   on `artifacts/manifest.json` and skip before ever reaching it.
//!
//! Swapping in the real bindings later means deleting this crate from the
//! workspace and pointing the `xla` dependency at xla-rs; no call-site
//! changes.

use std::fmt;

/// Stub error type; rendered with `{:?}` at the call sites, like the
/// status wrapper of the real bindings.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

const STUB_MSG: &str =
    "PJRT runtime unavailable: this workspace builds against the std-only \
     `xla` stub (rust/shims/xla). Simulate mode (`grace-moe simulate` / \
     `compare` / `components` / `placement`) never touches PJRT; execute \
     mode (`serve`, losslessness tests) needs the native xla_extension \
     bindings wired into the workspace";

fn stub_err<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!("{what}: {STUB_MSG}")))
}

// ---------------------------------------------------------------------------
// Literal: real host-side tensor marshalling
// ---------------------------------------------------------------------------

/// Element types a [`Literal`] can hold (the engine only marshals f32
/// activations/weights and i32 ids).
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(vals: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<&[Self]>;
    /// Dtype name used in error messages.
    const DTYPE: &'static str;
}

/// Storage of one literal (public only so `NativeType` can be implemented
/// here; treat as opaque).
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(vals: Vec<f32>) -> Data {
        Data::F32(vals)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "f32";
}

impl NativeType for i32 {
    fn wrap(vals: Vec<i32>) -> Data {
        Data::I32(vals)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
    const DTYPE: &'static str = "i32";
}

/// Host tensor: flat data plus row-major dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        Literal {
            dims: vec![vals.len() as i64],
            data: T::wrap(vals.to_vec()),
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(val: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![val]) }
    }

    /// Tuple literal (what executables return under `return_tuple=True`).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(elements) }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Elements held (tuples: number of components).
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Same data, new dims; errors when the element counts disagree.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if dims.iter().any(|&d| d < 0) {
            return Err(Error(format!("negative dim in {dims:?}")));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {} != {n}",
                self.dims,
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Flat host copy; errors on dtype mismatch or tuples.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).map(<[T]>::to_vec).ok_or_else(|| {
            Error(format!(
                "literal is not a dense {} tensor (have {})",
                T::DTYPE,
                match &self.data {
                    Data::F32(_) => "f32",
                    Data::I32(_) => "i32",
                    Data::Tuple(_) => "tuple",
                }
            ))
        })
    }

    /// Decompose a tuple literal into its components; a non-tuple literal
    /// decomposes into itself (single-output executables).
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.data {
            Data::Tuple(elements) => Ok(elements),
            _ => Ok(vec![self]),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT surface: loud stubs
// ---------------------------------------------------------------------------

/// Parsed HLO-text module (text retained verbatim; the stub validates only
/// that the file exists and looks like HLO).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error(format!("{path}: not HLO text")));
        }
        Ok(HloModuleProto { text })
    }

    pub fn as_text(&self) -> &str {
        &self.text
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }

    pub fn as_text(&self) -> &str {
        &self.text
    }
}

/// Stub PJRT client: construction fails with the actionable message above.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        stub_err("PjRtClient::compile")
    }
}

/// Stub compiled executable (unreachable — `compile` never succeeds).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L])
                      -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.dims(), &[2, 3]);
        assert_eq!(m.to_vec::<f32>().unwrap(),
                   vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(5i32);
        assert_eq!(s.dims().len(), 0);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![5]);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        // non-tuples decompose into themselves
        assert_eq!(s.clone().to_tuple().unwrap(), vec![s]);
    }

    #[test]
    fn client_is_a_loud_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("shims/xla"), "{err}");
    }
}
