//! Std-only shim of the `anyhow` error API.
//!
//! The offline registry has no crates, so this vendors the sliver of
//! `anyhow` the workspace actually uses: the type-erased [`Error`], the
//! [`Result`] alias, and the [`anyhow!`] / [`bail!`] / [`ensure!`] macros.
//! Semantics follow upstream where they matter:
//!
//! * `Error` deliberately does **not** implement `std::error::Error` —
//!   that is what makes the blanket `From<E: std::error::Error>` impl
//!   (and therefore `?` on any std error) coherent,
//! * `anyhow!` accepts a bare format literal, a single `Display` value,
//!   or a format string with arguments,
//! * `ensure!`/`bail!` early-return an `Err` from the enclosing function.
//!
//! No backtraces, no downcasting, no context chains — nothing in the
//! workspace needs them; add them here the day something does.

use std::error::Error as StdError;
use std::fmt;

/// Type-erased error: a boxed `std::error::Error` with `Display`/`Debug`
/// forwarding. Construct via [`Error::msg`], [`Error::new`], `?`, or the
/// [`anyhow!`] macro.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted, exactly like
/// upstream (`anyhow::Result<T>` and `anyhow::Result<T, E>` both work).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Ad-hoc message error backing [`Error::msg`] and the macros.
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Error from anything printable (the `anyhow!("…")` path).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Error wrapping a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Borrow the underlying error (chain inspection / tests).
    pub fn as_std(&self) -> &(dyn StdError + Send + Sync + 'static) {
        &*self.inner
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Upstream prints the message then the cause chain; we print the
        // message and any sources on following lines.
        fmt::Display::fmt(&self.inner, f)?;
        let mut source = self.inner.source();
        while let Some(cause) = source {
            write!(f, "\ncaused by: {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

// The load-bearing impl: `?` converts any std error into `Error`. This is
// only coherent because `Error` itself is not a `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format literal, a `Display` value, or a
/// format string plus arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return `Err(anyhow!(…))` from the enclosing function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return `Err(anyhow!(…))` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: `",
                               ::std::stringify!($cond), "`")
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn anyhow_macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let x = 7;
        let captured = anyhow!("x = {x}");
        assert_eq!(captured.to_string(), "x = 7");
        let args = anyhow!("{} + {}", 1, 2);
        assert_eq!(args.to_string(), "1 + 2");
        let display_value = anyhow!(String::from("owned message"));
        assert_eq!(display_value.to_string(), "owned message");
    }

    #[test]
    fn bail_and_ensure_early_return() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "too big: {n}");
            if n == 3 {
                bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("1 + 1 == 3"));
    }

    #[test]
    fn collect_into_result() {
        let ok: Result<Vec<usize>> =
            (0..3).map(Ok).collect::<Result<Vec<usize>>>();
        assert_eq!(ok.unwrap(), vec![0, 1, 2]);
    }
}
